//! Chaos soak: the serving tier under seeded fault injection
//! (ISSUE 9 acceptance).
//!
//! The soak drives thousands of requests through a server whose
//! connection and worker paths are being actively sabotaged by
//! [`bless::faults`] — stalled sockets, dropped connections, truncated
//! replies, panicking workers, failing engines — and asserts the
//! robustness contract:
//!
//! * every request ends in a score, a typed error code, or a clean
//!   connection error the client recovers from by reconnecting — no
//!   request ever hangs (the whole body runs under a watchdog timeout);
//! * the worker pool never shrinks: after the storm, with faults
//!   disarmed, the same server answers everything;
//! * a model quarantined by its circuit breaker recovers through the
//!   half-open probe once the fault goes away.
//!
//! The fault plan is seeded, so a failure reproduces exactly. Tests in
//! this binary serialize on a lock because the fault registry is
//! process-global. With `CHAOS_BENCH_OUT=path` the soak writes a
//! `BENCH_chaos.json` summary for CI artifact upload.

mod common;

use bless::faults::{self, FaultPlan, FaultPoint, FaultRule};
use bless::linalg::Matrix;
use bless::serve::{self, Client, ModelArtifact, ServeConfig};
use common::with_timeout;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// The fault registry is process-global; tests must not overlap.
fn faults_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

/// Disarms fault injection when dropped, so a panicking test cannot
/// leave the registry armed for the next one.
struct Disarm;
impl Drop for Disarm {
    fn drop(&mut self) {
        faults::configure(None);
    }
}

fn tiny_artifact() -> ModelArtifact {
    ModelArtifact {
        sigma: 1.0,
        centers: Matrix::from_fn(8, 3, |i, j| ((i * 3 + j) as f64 * 0.37).sin()),
        alpha: (0..8).map(|i| 0.25 * (i as f64 - 3.5)).collect(),
        trained_n: 8,
        dataset: "chaos".to_string(),
    }
}

#[derive(Default)]
struct SoakTally {
    ok: AtomicU64,
    typed_errors: AtomicU64,
    conn_resets: AtomicU64,
}

/// One soak client: `per_thread` requests, reconnecting whenever the
/// chaos harness kills its connection mid-exchange. Returns only when
/// every request has been accounted for.
fn soak_client(addr: std::net::SocketAddr, seed: u64, per_thread: u64, tally: &SoakTally) {
    let mut client = Client::connect(addr).expect("initial connect");
    for i in 0..per_thread {
        let id = seed * 1_000_000 + i;
        let x = [0.1 * (id % 17) as f64, -0.2 * (id % 13) as f64, 0.05 * (id % 7) as f64];
        // a generous per-request deadline doubles as the "nothing may
        // hang" guarantee at the protocol level
        match client.predict_within(id, &x, 5_000) {
            Ok((y, _)) => {
                assert!(y.is_finite(), "request {id} got a non-finite score");
                tally.ok.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                let msg = e.to_string();
                if msg.contains('[') {
                    // a structured `{"error":…,"code":…}` reply — the
                    // server answered even though a fault fired
                    tally.typed_errors.fetch_add(1, Ordering::Relaxed);
                } else {
                    // the connection itself was killed (conn.drop /
                    // conn.truncate); recover by reconnecting
                    tally.conn_resets.fetch_add(1, Ordering::Relaxed);
                    client = Client::connect(addr).expect("reconnect after fault");
                }
            }
        }
    }
}

/// The headline soak: ≥5k requests, ≥200 injected faults, every request
/// resolved, pool intact afterwards.
#[test]
fn soak_survives_a_mixed_fault_storm() {
    let _guard = faults_lock().lock().unwrap_or_else(|e| e.into_inner());
    let _disarm = Disarm;
    with_timeout(240, || {
        const THREADS: u64 = 8;
        const PER_THREAD: u64 = 640; // 5120 requests total
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(2)
            .max_batch(16)
            .linger(Duration::from_millis(1))
            .cache_capacity(0)
            .max_queue(0)
            .io_timeout(Some(Duration::from_secs(10)))
            // the storm makes consecutive failures likely; keep the
            // breaker out of this test (it has its own below) so every
            // request exercises the full path
            .breaker_threshold(0)
            .build()
            .unwrap();
        let handle = serve::start(tiny_artifact(), &cfg).unwrap();
        let addr = handle.addr();

        let injected_before = faults::total_injected();
        let plan = FaultPlan::seeded(0xC0FFEE)
            .with(FaultPoint::ConnDelay, FaultRule { p: 0.02, ms: 2 })
            .with(FaultPoint::ConnDrop, FaultRule { p: 0.02, ms: 0 })
            .with(FaultPoint::ConnTruncate, FaultRule { p: 0.02, ms: 0 })
            .with(FaultPoint::WorkerPanic, FaultRule { p: 0.05, ms: 0 })
            .with(FaultPoint::EngineError, FaultRule { p: 0.05, ms: 0 });
        faults::configure(Some(plan));

        let tally = Arc::new(SoakTally::default());
        let t0 = Instant::now();
        let workers: Vec<_> = (0..THREADS)
            .map(|t| {
                let tally = Arc::clone(&tally);
                std::thread::spawn(move || soak_client(addr, t, PER_THREAD, &tally))
            })
            .collect();
        for w in workers {
            w.join().expect("soak client must not die");
        }
        let elapsed = t0.elapsed();
        // read the injection tallies BEFORE disarming: the counters live
        // with the armed plan and reset with it
        let injected = faults::total_injected() - injected_before;
        let point_counts = faults::injected_counts();
        faults::configure(None);

        let ok = tally.ok.load(Ordering::Relaxed);
        let typed = tally.typed_errors.load(Ordering::Relaxed);
        let resets = tally.conn_resets.load(Ordering::Relaxed);
        let total = ok + typed + resets;
        assert_eq!(total, THREADS * PER_THREAD, "every request must be accounted for");
        assert!(ok > 0, "the storm must not starve out every success");
        assert!(injected >= 200, "want ≥200 injected faults for a real soak, got {injected}");

        // pool intact: with faults off, the same server answers a full
        // sweep with zero failures — no worker thread was permanently
        // lost to a panic
        let mut client = Client::connect(addr).unwrap();
        for i in 0..64u64 {
            let (y, _) = client.predict(10_000_000 + i, &[0.3, -0.1, 0.2]).unwrap();
            assert!(y.is_finite());
        }
        let stats = handle.stats();
        assert_eq!(
            stats.worker_panics, stats.worker_respawns,
            "every worker panic must have respawned its tick loop"
        );

        if let Ok(path) = std::env::var("CHAOS_BENCH_OUT") {
            let by_point: Vec<String> = point_counts
                .into_iter()
                .map(|(name, n)| format!("\"{name}\":{n}"))
                .collect();
            let json = format!(
                "{{\"requests\":{total},\"ok\":{ok},\"typed_errors\":{typed},\
                 \"conn_resets\":{resets},\"faults_injected\":{injected},\
                 \"worker_panics\":{},\"deadline_exceeded\":{},\
                 \"elapsed_s\":{:.3},\"by_point\":{{{}}}}}",
                stats.worker_panics,
                stats.deadline_exceeded,
                elapsed.as_secs_f64(),
                by_point.join(",")
            );
            std::fs::write(&path, json).expect("writing CHAOS_BENCH_OUT");
            eprintln!("wrote chaos bench summary to {path}");
        }
        handle.shutdown();
    });
}

/// A model whose every batch panics trips its breaker into quarantine;
/// once the fault clears, the half-open probe re-admits it and traffic
/// flows again — no restart needed.
#[test]
fn quarantined_model_recovers_once_the_fault_clears() {
    let _guard = faults_lock().lock().unwrap_or_else(|e| e.into_inner());
    let _disarm = Disarm;
    with_timeout(120, || {
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .max_batch(4)
            .linger(Duration::from_millis(1))
            .cache_capacity(0)
            .breaker_threshold(3)
            .breaker_cooldown(Duration::from_millis(150))
            .build()
            .unwrap();
        let handle = serve::start(tiny_artifact(), &cfg).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();

        faults::configure(Some(FaultPlan::seeded(7).with(FaultPoint::WorkerPanic, FaultRule { p: 1.0, ms: 0 })));
        // every batch panics → internal errors pile up → after the third
        // consecutive failure the breaker opens and answers up front
        let mut saw_quarantine = false;
        for i in 0..50u64 {
            match client.predict(i, &[0.1, 0.2, 0.3]) {
                Err(e) if e.to_string().contains("[quarantined]") => {
                    saw_quarantine = true;
                    break;
                }
                Err(e) if e.to_string().contains("[internal]") => continue,
                other => panic!("expected internal/quarantined, got {other:?}"),
            }
        }
        assert!(saw_quarantine, "the breaker must trip under a panic storm");
        let stats = handle.model_stats("default").unwrap();
        assert!(stats.worker_panics >= 3, "got {} panics", stats.worker_panics);
        assert!(stats.quarantined >= 1);

        // the engine heals (faults off); after the cooldown the next
        // request is the half-open probe — it succeeds and closes the
        // breaker for everyone after it
        faults::configure(None);
        std::thread::sleep(Duration::from_millis(200));
        let t0 = Instant::now();
        loop {
            match client.predict(1_000, &[0.1, 0.2, 0.3]) {
                Ok((y, _)) => {
                    assert!(y.is_finite());
                    break;
                }
                Err(_) if t0.elapsed() < Duration::from_secs(10) => {
                    std::thread::sleep(Duration::from_millis(50));
                }
                Err(e) => panic!("model never recovered from quarantine: {e}"),
            }
        }
        for i in 0..16u64 {
            let (y, _) = client.predict(2_000 + i, &[0.4, -0.2, 0.1]).unwrap();
            assert!(y.is_finite());
        }
        handle.shutdown();
    });
}

/// Regression: a request that wins the half-open probe slot but is
/// served from the cache never reaches a worker, so no breaker verdict
/// arrives from the batch path. The slot must be released — before the
/// fix the breaker wedged half-open forever and every later request
/// answered `quarantined` while `/healthz` reported the model ready.
#[test]
fn cache_hit_probe_releases_the_half_open_slot() {
    let _guard = faults_lock().lock().unwrap_or_else(|e| e.into_inner());
    let _disarm = Disarm;
    with_timeout(120, || {
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .max_batch(4)
            .linger(Duration::from_millis(1))
            .cache_capacity(64)
            .breaker_threshold(3)
            .breaker_cooldown(Duration::from_millis(150))
            .build()
            .unwrap();
        let handle = serve::start(tiny_artifact(), &cfg).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();

        // seed the cache while the engine is healthy
        let hot = [0.5, 0.5, 0.5];
        let (y0, _) = client.predict(1, &hot).unwrap();

        // a panic storm on distinct (uncached) queries trips the breaker
        faults::configure(Some(
            FaultPlan::seeded(11).with(FaultPoint::WorkerPanic, FaultRule { p: 1.0, ms: 0 }),
        ));
        let mut saw_quarantine = false;
        for i in 0..50u64 {
            let x = [i as f64, -(i as f64), 1.0 + i as f64];
            match client.predict(100 + i, &x) {
                Err(e) if e.to_string().contains("[quarantined]") => {
                    saw_quarantine = true;
                    break;
                }
                Err(e) if e.to_string().contains("[internal]") => continue,
                other => panic!("expected internal/quarantined, got {other:?}"),
            }
        }
        assert!(saw_quarantine, "the breaker must trip under a panic storm");

        // the engine heals; after the cooldown the first request in is
        // the cached one — it wins the probe slot yet never exercises
        // the engine, so it must hand the slot back
        faults::configure(None);
        std::thread::sleep(Duration::from_millis(200));
        let (y1, cached) = client.predict(1_000, &hot).expect("cache hit must serve");
        assert!(cached, "the probe request must be served from cache");
        assert_eq!(y1, y0);

        // the released slot lets the very next cache miss probe for
        // real: it predicts and closes the breaker — a wedged breaker
        // would answer `quarantined` here forever
        let (y2, cached2) = client
            .predict(1_001, &[0.7, -0.3, 0.9])
            .expect("released probe slot must re-admit a real probe");
        assert!(!cached2);
        assert!(y2.is_finite());
        for i in 0..8u64 {
            let (y, _) = client.predict(2_000 + i, &[0.2, 0.1, -0.4]).unwrap();
            assert!(y.is_finite());
        }
        handle.shutdown();
    });
}

/// Same seed → same fault sequence: the soak's storm is replayable, so
/// a chaos failure in CI reproduces locally byte-for-byte.
#[test]
fn fault_plans_replay_deterministically_across_arms() {
    let _guard = faults_lock().lock().unwrap_or_else(|e| e.into_inner());
    let _disarm = Disarm;
    with_timeout(60, || {
        let plan = FaultPlan::seeded(42).with(FaultPoint::ConnDrop, FaultRule { p: 0.3, ms: 0 });
        faults::configure(Some(plan.clone()));
        let first: Vec<bool> = (0..64).map(|_| faults::fire(FaultPoint::ConnDrop)).collect();
        faults::configure(Some(plan));
        let second: Vec<bool> = (0..64).map(|_| faults::fire(FaultPoint::ConnDrop)).collect();
        assert_eq!(first, second, "re-arming the same plan must replay the same draws");
        assert!(first.iter().any(|&b| b) && first.iter().any(|&b| !b));
        faults::configure(None);
    });
}
