//! Shared helpers for the integration-test binaries.

use std::sync::mpsc;
use std::time::Duration;

/// Run `f` on a helper thread and panic if it has not finished within
/// `secs`. A timed-out test leaks its helper thread (the process is
/// about to die anyway) — the point is that CI fails fast with the
/// test's name instead of hanging for hours on a wedged socket. A
/// panicking test body is *not* a timeout: its sender drops on unwind
/// (`Disconnected`), and the original panic is re-raised unchanged.
pub fn with_timeout(secs: u64, f: impl FnOnce() + Send + 'static) {
    let (tx, rx) = mpsc::channel();
    let runner = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("test timed out after {secs}s (server wedged?)")
        }
        // Ok = clean finish; Disconnected = the body panicked — join and
        // propagate the real panic payload instead of mislabeling it
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => {
            if let Err(payload) = runner.join() {
                std::panic::resume_unwind(payload);
            }
        }
    }
}
