//! End-to-end tests of the serving tier: train → save → load → serve →
//! concurrent TCP traffic, checked against the direct in-process predict
//! path (ISSUE 1 acceptance criteria).
//!
//! Every test body runs under [`common::with_timeout`]: a wedged server
//! fails the suite in seconds instead of hanging CI until the job
//! timeout.

mod common;

use bless::bless::{bless, BlessConfig};
use bless::data::susy_like;
use bless::falkon::Falkon;
use bless::kernels::{Gaussian, NativeEngine};
use bless::linalg::Matrix;
use bless::rng::Rng;
use bless::serve::{self, Client, ModelArtifact, Predictor, ServeConfig};
use common::with_timeout;
use std::sync::Arc;
use std::time::Duration;

/// Train a small FALKON-BLESS model and package it as an artifact,
/// returning a held-out query matrix alongside.
fn trained_artifact() -> (ModelArtifact, Matrix) {
    let mut rng = Rng::seeded(7);
    let ds = susy_like(800, &mut rng);
    let (train, test) = ds.split(0.25, &mut rng);
    let eng = NativeEngine::new(train.x.clone(), Gaussian::new(4.0));
    let path = bless(&eng, 1e-3, &BlessConfig::default(), &mut rng);
    let model = Falkon::new(&eng, path.final_set(), 1e-5)
        .unwrap()
        .fit(&train.y, 10, None)
        .unwrap();
    let art = ModelArtifact::from_fitted(&model, &eng, "susy-like").unwrap();

    // sanity: the artifact reproduces the training-side predict path
    // bit-exactly on the held-out queries
    let direct = model.predict(&eng, &test.x);
    let served = Predictor::new(&art).predict_batch(&test.x).unwrap();
    for (a, b) in direct.iter().zip(&served) {
        assert_eq!(a.to_bits(), b.to_bits(), "artifact drifted from model: {a} vs {b}");
    }
    (art, test.x)
}

fn tmp_path(tag: &str, ext: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("bless-serve-it-{}-{tag}.{ext}", std::process::id()))
}

/// The headline test: `train --save` → `serve` in-process, 8 concurrent
/// client threads over TCP, responses match direct predict to 1e-10 and
/// the server stats show real coalescing (mean batch size > 1).
#[test]
fn concurrent_clients_match_direct_predictions_and_coalesce() {
    with_timeout(120, || {
        let (art, queries) = trained_artifact();

        // persist + reload through the *binary* codec: the server must
        // run off the loaded artifact
        let path = tmp_path("e2e", "bin");
        art.save(&path).unwrap();
        let loaded = ModelArtifact::load(&path).unwrap();
        std::fs::remove_file(&path).ok();

        let reference = Predictor::new(&loaded);
        let expected = Arc::new(reference.predict_batch(&queries).unwrap());
        let queries = Arc::new(queries);

        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(2)
            .max_batch(16)
            .linger(Duration::from_millis(5))
            .cache_capacity(0) // cache off: every request exercises the GEMM path
            .max_queue(0) // unbounded: this test is about coalescing, not shedding
            .build()
            .unwrap();
        let handle = serve::start(loaded, &cfg).unwrap();
        let addr = handle.addr();

        const CLIENTS: usize = 8;
        const PER_CLIENT: usize = 25;
        let mut joins = Vec::new();
        for c in 0..CLIENTS {
            let queries = Arc::clone(&queries);
            let expected = Arc::clone(&expected);
            joins.push(std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                for k in 0..PER_CLIENT {
                    let row = (c * 31 + k * 7) % queries.rows();
                    let id = (c * PER_CLIENT + k) as u64;
                    let (y, _cached) = client.predict(id, queries.row(row)).unwrap();
                    let want = expected[row];
                    assert!(
                        (y - want).abs() <= 1e-10,
                        "client {c} req {k}: served {y} vs direct {want}"
                    );
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }

        let stats = handle.stats();
        assert_eq!(stats.requests, (CLIENTS * PER_CLIENT) as u64);
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.shed, 0);
        assert_eq!(stats.batched, stats.requests, "every request must flow through a batch");
        assert!(
            stats.mean_batch() > 1.0,
            "requests were not coalesced: {} batches for {} requests (mean {:.2})",
            stats.batches,
            stats.requests,
            stats.mean_batch()
        );

        // the wire-level stats agree with the in-process counters
        let mut client = Client::connect(addr).unwrap();
        let wire = client.stats().unwrap();
        assert_eq!(wire.requests, stats.requests);
        assert_eq!(wire.batches, stats.batches);
        drop(client);
        handle.shutdown();
    });
}

/// Repeated-query traffic is served from the LRU cache and flagged so.
#[test]
fn repeated_queries_hit_cache_over_the_wire() {
    with_timeout(120, || {
        let (art, queries) = trained_artifact();
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .max_batch(8)
            .linger(Duration::from_millis(1))
            .cache_capacity(64)
            .max_queue(0)
            .build()
            .unwrap();
        let handle = serve::start(art, &cfg).unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();

        let q = queries.row(3);
        let (y1, c1) = client.predict(1, q).unwrap();
        let (y2, c2) = client.predict(2, q).unwrap();
        assert!(!c1, "first query cannot be a cache hit");
        assert!(c2, "identical repeat should be a cache hit");
        assert_eq!(y1.to_bits(), y2.to_bits());
        assert_eq!(handle.stats().cache_hits, 1);
        handle.shutdown();
    });
}

/// A client asking for the wrong dimensionality gets an error response
/// (not a hang, not a panic), and valid traffic continues afterwards.
#[test]
fn dimension_mismatch_is_rejected_per_request() {
    with_timeout(120, || {
        let (art, queries) = trained_artifact();
        let d = art.d();
        let handle = serve::start(
            art,
            &ServeConfig::builder().addr("127.0.0.1:0").build().unwrap(),
        )
        .unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        assert!(client.predict(1, &vec![0.0; d + 1]).is_err());
        client.predict(2, queries.row(0)).unwrap(); // connection survives
        assert_eq!(handle.stats().errors, 1);
        handle.shutdown();
    });
}

/// Minimal HTTP GET against the metrics listener → (status line, body).
fn http_get(addr: std::net::SocketAddr, path: &str) -> (String, String) {
    use std::io::{Read as _, Write as _};
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let (head, body) = buf.split_once("\r\n\r\n").expect("malformed HTTP response");
    (head.lines().next().unwrap_or("").to_string(), body.to_string())
}

/// `/metrics` speaks well-formed Prometheus text exposition with the
/// per-model series, `/healthz` and `/varz` parse as JSON, and unknown
/// paths 404 — all on a listener separate from the prediction socket.
#[test]
fn metrics_and_healthz_scrape_well_formed() {
    with_timeout(120, || {
        let (art, queries) = trained_artifact();
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .metrics_addr("127.0.0.1:0")
            .build()
            .unwrap();
        let handle = serve::start(art, &cfg).unwrap();
        let maddr = handle.metrics_addr().expect("metrics listener is up");

        let mut client = Client::connect(handle.addr()).unwrap();
        for k in 0..5 {
            client.predict(k as u64, queries.row(k)).unwrap();
        }

        let (status, body) = http_get(maddr, "/metrics");
        assert!(status.contains("200"), "scrape failed: {status}");
        // exposition-format grammar: every non-comment line is
        // `name{labels} value` with a parseable numeric value
        for line in body.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
            let (series, value) = line.rsplit_once(' ').expect("sample line has a value");
            assert!(value.parse::<f64>().is_ok(), "non-numeric sample value in {line:?}");
            assert!(
                series.chars().all(|c| c.is_ascii_alphanumeric() || "_{}=\",.+:-".contains(c)),
                "unexpected character in series {series:?}"
            );
        }
        assert!(body.contains("bless_serve_requests_total{model=\"default\"} 5"), "{body}");
        assert!(body.contains("# TYPE bless_serve_latency_us histogram"));
        assert!(body.contains("bless_serve_latency_us_count{model=\"default\"} 5"));
        assert!(body.contains("bless_serve_batch_size_bucket{model=\"default\""));
        assert!(body.contains("bless_serve_queue_depth{model=\"default\"}"));

        let (status, body) = http_get(maddr, "/healthz");
        assert!(status.contains("200"), "healthz failed: {status}");
        let health = bless::util::json::Json::parse(&body).expect("healthz is JSON");
        assert_eq!(health.get("ok"), Some(&bless::util::json::Json::Bool(true)));

        let (status, body) = http_get(maddr, "/varz");
        assert!(status.contains("200"), "varz failed: {status}");
        let varz = bless::util::json::Json::parse(&body).expect("varz is JSON");
        let requests = varz
            .get("models")
            .and_then(|m| m.get("default"))
            .and_then(|m| m.get("requests"))
            .and_then(|v| v.as_f64());
        assert_eq!(requests, Some(5.0));

        let (status, _) = http_get(maddr, "/nope");
        assert!(status.contains("404"), "unknown path must 404: {status}");

        handle.shutdown();
    });
}

/// `{"op":"shutdown"}` over the wire stops the server: `join` returns
/// and the queue refuses new work.
#[test]
fn wire_shutdown_stops_the_server() {
    with_timeout(120, || {
        let (art, _) = trained_artifact();
        let handle = serve::start(
            art,
            &ServeConfig::builder().addr("127.0.0.1:0").build().unwrap(),
        )
        .unwrap();
        let mut client = Client::connect(handle.addr()).unwrap();
        client.shutdown().unwrap();
        assert!(handle.is_shut_down());
        handle.join();
    });
}
