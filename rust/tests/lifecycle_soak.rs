//! Retrain chaos soak: the continuous-training lifecycle under seeded
//! fault injection (ISSUE 10 acceptance).
//!
//! A live server keeps answering traffic while retrain cycles are being
//! actively sabotaged — panicking trainers (`train.panic`), a gate
//! forced to reject (`gate.fail`), checkpoints mutilated between read
//! and decode (`ckpt.corrupt`) — and the lifecycle contract holds:
//!
//! * the incumbent never stops serving: every concurrent request
//!   resolves to a finite score through every failed cycle, promotion
//!   and swap;
//! * the gate holds: a cycle that does not end in `Promoted` leaves the
//!   entry's version, predictor and counters untouched;
//! * a forced failure spike right after a promotion trips the breaker
//!   inside the probation window and triggers automatic rollback to the
//!   retained incumbent — in memory and on disk;
//! * warm-started refits converge in ≤ 1/3 of a cold fit's CG
//!   iterations at equal tolerance (written to `BENCH_retrain.json`
//!   via `RETRAIN_BENCH_OUT` for CI upload).
//!
//! Fault plans are seeded, so every storm replays exactly. Tests
//! serialize on a lock because the fault registry is process-global.

mod common;

use bless::data::susy_like;
use bless::falkon::{CheckpointSpec, Falkon, FitOptions};
use bless::faults::{self, FaultPlan, FaultPoint, FaultRule};
use bless::kernels::{Gaussian, NativeEngine};
use bless::leverage::WeightedSet;
use bless::lifecycle::{run_cycle, CycleOutcome, HoldoutGate, LifecycleConfig};
use bless::rng::Rng;
use bless::serve::{self, Client, ModelArtifact, Predictor, RetryPolicy, ServeConfig};
use common::with_timeout;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// The fault registry is process-global; tests must not overlap.
fn faults_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

/// Disarms fault injection when dropped, so a panicking test cannot
/// leave the registry armed for the next one.
struct Disarm;
impl Drop for Disarm {
    fn drop(&mut self) {
        faults::configure(None);
    }
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("bless-lcsoak-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Everything one retrain-soak world needs: a fitted incumbent on real
/// SUSY-like data, its training engine + center set for refits, and a
/// holdout gate cut from the same split.
struct World {
    engine: NativeEngine,
    set: WeightedSet,
    train_y: Vec<f64>,
    incumbent: ModelArtifact,
    gate: HoldoutGate,
    dim: usize,
}

fn build_world() -> World {
    let lambda = 1e-3;
    let mut rng = Rng::seeded(42);
    let ds = susy_like(600, &mut rng);
    let (train, holdout) = ds.split(0.25, &mut rng);
    let centers = Rng::seeded(7).sample_without_replacement(train.n(), 60);
    let set = WeightedSet::uniform(centers, lambda);
    let dim = train.d();
    let engine = NativeEngine::new(train.x.clone(), Gaussian::new(3.0));
    let model = Falkon::new(&engine, &set, lambda).unwrap().fit(&train.y, 8, None).unwrap();
    let incumbent = ModelArtifact::from_fitted(&model, &engine, "lcsoak").unwrap();
    // generous tolerance: drifted refits wobble around the incumbent's
    // holdout RMSE, and this soak tests the *machinery*, not the gate's
    // statistical sharpness (gate_scores_and_validates covers that)
    let gate = HoldoutGate::new(holdout.x.clone(), holdout.y.clone(), 0.5).unwrap();
    World { engine, set, train_y: train.y, incumbent, gate, dim }
}

/// Labels drifted deterministically by cycle number — what each retrain
/// cycle fits against.
fn drifted(y: &[f64], cycle: u64, amplitude: f64) -> Vec<f64> {
    y.iter()
        .enumerate()
        .map(|(i, v)| v + amplitude * (0.1 * i as f64 + 0.37 * cycle as f64).sin())
        .collect()
}

/// The headline soak: a three-phase seeded storm over `train.panic`,
/// `gate.fail` and `ckpt.corrupt` while a client hammers the server.
/// Every cycle outcome is accounted for, every request serves, and the
/// entry's version moves only on promotions.
#[test]
fn retrain_storm_never_interrupts_serving_and_the_gate_holds() {
    let _guard = faults_lock().lock().unwrap_or_else(|e| e.into_inner());
    let _disarm = Disarm;
    with_timeout(240, || {
        let w = build_world();
        let dir = tmp_dir("storm");
        let artifact_path = dir.join("serving.bin");
        w.incumbent.save(&artifact_path).unwrap();

        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(2)
            .max_batch(16)
            .linger(Duration::from_millis(1))
            .cache_capacity(0)
            .breaker_threshold(0) // rollback has its own test below
            .build()
            .unwrap();
        let handle = serve::start(w.incumbent.clone(), &cfg).unwrap();
        let entry = handle.entry("default").unwrap();
        let addr = handle.addr();

        // continuous traffic for the whole storm: every request must
        // resolve to a finite score, across every swap and failed cycle
        let stop_traffic = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let dim = w.dim;
        let traffic = {
            let stop = Arc::clone(&stop_traffic);
            let served = Arc::clone(&served);
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).expect("traffic connect");
                let policy = RetryPolicy { max_retries: 12, ..Default::default() };
                let mut i = 0u64;
                while !stop.load(Ordering::SeqCst) {
                    let x: Vec<f64> =
                        (0..dim).map(|j| 0.05 * ((i + j as u64) % 23) as f64 - 0.4).collect();
                    let (y, _) = client
                        .predict_with_retry(i, &x, &policy)
                        .expect("a request failed while the incumbent should be serving");
                    assert!(y.is_finite(), "request {i} got a non-finite score");
                    served.fetch_add(1, Ordering::Relaxed);
                    i += 1;
                }
            })
        };

        // trainer: warm refit on drifted labels, with a checkpoint it
        // tries to resume each cycle — under `ckpt.corrupt` p=1 every
        // resume attempt sees mutilated bytes and must cold-start the
        // CG state (warm α still applies), never panic
        let ckpt_path = dir.join("refit.ckpt");
        let mut last_alpha = w.incumbent.alpha.clone();
        let lambda = 1e-3;
        let cycle_of = |cycle: u64, alpha: &[f64]| -> anyhow::Result<ModelArtifact> {
            let y = drifted(&w.train_y, cycle, 0.02);
            let solver = Falkon::new(&w.engine, &w.set, lambda)?;
            let model = solver.fit_opts(
                &y,
                40,
                None,
                FitOptions {
                    tol: 1e-6,
                    warm_start: Some(alpha),
                    checkpoint: Some(CheckpointSpec {
                        path: ckpt_path.clone(),
                        every: 2,
                        resume: true,
                    }),
                },
            )?;
            ModelArtifact::from_fitted(&model, &w.engine, "lcsoak-drift")
        };

        let mut lcfg = LifecycleConfig::new(artifact_path.clone());
        lcfg.probation = Duration::from_millis(40);
        lcfg.poll = Duration::from_millis(5);
        let never_stop = AtomicBool::new(false);

        let mut incumbent = w.incumbent.clone();
        let run = |cycle: u64,
                   incumbent: &mut ModelArtifact,
                   last_alpha: &mut Vec<f64>,
                   tallies: &mut (u64, u64, u64)| {
            let alpha = last_alpha.clone();
            let outcome = run_cycle(
                &entry,
                incumbent,
                || cycle_of(cycle, &alpha),
                &w.gate,
                &lcfg,
                &never_stop,
            );
            match outcome {
                CycleOutcome::TrainFailed { reason } => {
                    assert!(!reason.is_empty());
                    tallies.0 += 1;
                }
                CycleOutcome::GateRejected { decision, quarantined_to } => {
                    assert!(decision.injected || !decision.pass);
                    // the quarantined candidate is a loadable artifact
                    let q = quarantined_to.expect("quarantine write must succeed");
                    assert!(ModelArtifact::load(&q).is_ok());
                    tallies.1 += 1;
                }
                CycleOutcome::Promoted { artifact, .. } => {
                    *last_alpha = artifact.alpha.clone();
                    *incumbent = artifact;
                    tallies.2 += 1;
                }
                CycleOutcome::RolledBack { .. } => {
                    panic!("no breaker in this storm — rollback is impossible")
                }
            }
        };

        // phase A — every trainer panics: all cycles contained, nothing
        // promoted, incumbent untouched
        faults::configure(Some(
            FaultPlan::seeded(0xA11)
                .with(FaultPoint::TrainPanic, FaultRule { p: 1.0, ms: 0 })
                .with(FaultPoint::CkptCorrupt, FaultRule { p: 1.0, ms: 0 }),
        ));
        let mut tallies = (0u64, 0u64, 0u64);
        for c in 1..=2u64 {
            run(c, &mut incumbent, &mut last_alpha, &mut tallies);
        }
        assert_eq!(tallies, (2, 0, 0), "phase A: every cycle must be a contained panic");
        assert_eq!(entry.version(), 1, "a failed train must never touch the entry");

        // phase B — the gate is forced to fail: candidates train fine
        // but are refused before any swap and parked for post-mortem
        faults::configure(Some(
            FaultPlan::seeded(0xB22)
                .with(FaultPoint::GateFail, FaultRule { p: 1.0, ms: 0 })
                .with(FaultPoint::CkptCorrupt, FaultRule { p: 1.0, ms: 0 }),
        ));
        for c in 3..=4u64 {
            run(c, &mut incumbent, &mut last_alpha, &mut tallies);
        }
        assert_eq!(tallies, (2, 2, 0), "phase B: every cycle must be gate-rejected");
        assert_eq!(entry.version(), 1, "a rejected candidate must never be swapped in");
        let probe: Vec<f64> = vec![0.1; w.dim];
        let pre_storm = entry.predictor().predict_one(&probe).unwrap();

        // phase C — the mixed storm: seeded coin flips over both points,
        // checkpoints corrupted throughout
        faults::configure(Some(
            FaultPlan::seeded(0xC33)
                .with(FaultPoint::TrainPanic, FaultRule { p: 0.3, ms: 0 })
                .with(FaultPoint::GateFail, FaultRule { p: 0.3, ms: 0 })
                .with(FaultPoint::CkptCorrupt, FaultRule { p: 1.0, ms: 0 }),
        ));
        for c in 5..=12u64 {
            run(c, &mut incumbent, &mut last_alpha, &mut tallies);
        }
        faults::configure(None);
        let (failed, rejected, promoted) = tallies;
        assert_eq!(failed + rejected + promoted, 12, "every cycle must be accounted for");

        // the gate held: the version moved exactly once per promotion
        assert_eq!(entry.version(), 1 + promoted, "version must move only on promotion");
        let snap = entry.stats.snapshot();
        assert_eq!(snap.promotions, promoted);
        assert_eq!(snap.rollbacks, 0);
        // promotions persisted: the serving artifact on disk is the last
        // incumbent, bit for bit
        let on_disk = ModelArtifact::load(&artifact_path).unwrap();
        assert_eq!(bits(&on_disk.alpha), bits(&incumbent.alpha));
        if promoted > 0 {
            let now = entry.predictor().predict_one(&probe).unwrap();
            assert_ne!(pre_storm.to_bits(), now.to_bits(), "a promotion must change the model");
        }

        // serving never stopped — and still works after the storm
        stop_traffic.store(true, Ordering::SeqCst);
        traffic.join().expect("traffic thread must not die");
        assert!(served.load(Ordering::Relaxed) > 100, "traffic must have flowed all along");
        let mut client = Client::connect(addr).unwrap();
        for i in 0..32u64 {
            let x: Vec<f64> = (0..w.dim).map(|j| 0.02 * (i + j as u64) as f64).collect();
            let (y, _) = client.predict(1_000_000 + i, &x).unwrap();
            assert!(y.is_finite());
        }
        handle.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    });
}

/// A promotion that passes the gate but collapses under live traffic:
/// an engine-failure spike trips the breaker inside the probation
/// window, the lifecycle rolls back to the retained incumbent — in
/// memory and on disk — and serving recovers without a restart.
#[test]
fn failure_spike_after_promotion_rolls_back_automatically() {
    let _guard = faults_lock().lock().unwrap_or_else(|e| e.into_inner());
    let _disarm = Disarm;
    with_timeout(240, || {
        let w = build_world();
        let dir = tmp_dir("rollback");
        let artifact_path = dir.join("serving.bin");
        w.incumbent.save(&artifact_path).unwrap();

        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .max_batch(4)
            .linger(Duration::from_millis(1))
            .cache_capacity(0)
            .breaker_threshold(3)
            .breaker_cooldown(Duration::from_millis(150))
            .build()
            .unwrap();
        let handle = serve::start(w.incumbent.clone(), &cfg).unwrap();
        let entry = handle.entry("default").unwrap();
        let addr = handle.addr();

        // the saboteur: waits for the promotion to land (version 2),
        // arms a total engine-failure storm, and hammers requests until
        // the breaker trips — all while run_cycle watches probation
        let dim = w.dim;
        let saboteur = {
            let entry = Arc::clone(&entry);
            std::thread::spawn(move || {
                let t0 = Instant::now();
                while entry.version() < 2 {
                    assert!(t0.elapsed() < Duration::from_secs(60), "promotion never landed");
                    std::thread::sleep(Duration::from_millis(2));
                }
                faults::configure(Some(
                    FaultPlan::seeded(0xDEAD)
                        .with(FaultPoint::EngineError, FaultRule { p: 1.0, ms: 0 }),
                ));
                let mut client = Client::connect(addr).expect("saboteur connect");
                for i in 0..200u64 {
                    let x: Vec<f64> = (0..dim).map(|j| 0.01 * (i + j as u64) as f64).collect();
                    match client.predict(500_000 + i, &x) {
                        Err(e) if e.to_string().contains("[quarantined]") => {
                            faults::configure(None);
                            return;
                        }
                        Err(_) => continue, // [internal] while failures accumulate
                        Ok(_) => continue,
                    }
                }
                faults::configure(None);
                panic!("the failure spike never tripped the breaker");
            })
        };

        let lambda = 1e-3;
        let trainer = || -> anyhow::Result<ModelArtifact> {
            let y = drifted(&w.train_y, 1, 0.02);
            let solver = Falkon::new(&w.engine, &w.set, lambda)?;
            let model = solver.refit(&y, 40, 1e-6, &w.incumbent.alpha)?;
            ModelArtifact::from_fitted(&model, &w.engine, "lcsoak-spike")
        };
        let mut lcfg = LifecycleConfig::new(artifact_path.clone());
        lcfg.probation = Duration::from_secs(30); // the spike ends it long before
        lcfg.poll = Duration::from_millis(2);
        let never_stop = AtomicBool::new(false);
        let outcome =
            run_cycle(&entry, &w.incumbent, trainer, &w.gate, &lcfg, &never_stop);
        saboteur.join().expect("saboteur must not die");

        let trips = match outcome {
            CycleOutcome::RolledBack { trips, .. } => trips,
            other => panic!("expected RolledBack, got {other:?}"),
        };
        assert!(trips >= 1);
        // promote (2) then rollback swap (3); both counters recorded
        assert_eq!(entry.version(), 3);
        let snap = entry.stats.snapshot();
        assert_eq!((snap.promotions, snap.rollbacks), (1, 1));
        assert!(!entry.breaker.is_open(), "rollback must reset the breaker");

        // the incumbent serves again, bit-for-bit — in memory...
        let probe: Vec<f64> = (0..w.dim).map(|j| 0.03 * j as f64 - 0.2).collect();
        let want = Predictor::new(&w.incumbent).predict_one(&probe).unwrap();
        let got = entry.predictor().predict_one(&probe).unwrap();
        assert_eq!(want.to_bits(), got.to_bits(), "rollback must restore the incumbent");
        // ...and on disk, so a restart reloads what is actually serving
        let on_disk = ModelArtifact::load(&artifact_path).unwrap();
        assert_eq!(bits(&on_disk.alpha), bits(&w.incumbent.alpha));

        // live traffic flows again with no restart (faults are disarmed
        // and the rollback closed the breaker)
        let mut client = Client::connect(addr).unwrap();
        let policy = RetryPolicy { max_retries: 12, ..Default::default() };
        for i in 0..16u64 {
            let x: Vec<f64> = (0..w.dim).map(|j| 0.02 * (i + j as u64) as f64).collect();
            let (y, _) = client.predict_with_retry(700_000 + i, &x, &policy).unwrap();
            assert!(y.is_finite());
        }
        handle.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    });
}

/// Warm-started refits are what make a tight retrain period affordable:
/// seeded from the incumbent `α` on mildly drifted labels, CG must
/// converge in at most a third of a cold fit's iterations at the same
/// tolerance. `RETRAIN_BENCH_OUT=path` records the measurement as JSON
/// for CI artifact upload.
#[test]
fn warm_refit_needs_at_most_a_third_of_cold_iterations() {
    with_timeout(240, || {
        let lambda = 1e-3;
        let tol = 1e-6;
        let mut rng = Rng::seeded(42);
        let ds = susy_like(500, &mut rng);
        let (train, _holdout) = ds.split(0.25, &mut rng);
        let centers = Rng::seeded(9).sample_without_replacement(train.n(), 60);
        let set = WeightedSet::uniform(centers, lambda);
        let engine = NativeEngine::new(train.x.clone(), Gaussian::new(3.0));
        let solver = Falkon::new(&engine, &set, lambda).unwrap();

        let cold = solver
            .fit_opts(&train.y, 200, None, FitOptions { tol, ..Default::default() })
            .unwrap();
        // mild drift: the incumbent is already close to the new solution
        let y2 = drifted(&train.y, 1, 1e-5);
        let cold2 = solver
            .fit_opts(&y2, 200, None, FitOptions { tol, ..Default::default() })
            .unwrap();
        let warm = solver.refit(&y2, 200, tol, &cold.alpha).unwrap();

        let (cold_iters, warm_iters) = (cold2.iterations.len(), warm.iterations.len());
        assert!(
            warm_iters * 3 <= cold_iters,
            "warm refit took {warm_iters} CG iterations vs cold {cold_iters} — want ≤ 1/3"
        );
        // equal tolerance means equal answers (to the shared tolerance)
        let pw = solver.predict_train(&warm.alpha);
        let pc = solver.predict_train(&cold2.alpha);
        let err = bless::data::rmse(&pw, &pc);
        let scale = bless::linalg::norm2(&pc) / (pc.len() as f64).sqrt();
        assert!(err < 1e-4 * scale.max(1.0), "warm vs cold rmse {err}");

        if let Ok(path) = std::env::var("RETRAIN_BENCH_OUT") {
            let json = format!(
                "{{\"cold_iters\":{cold_iters},\"warm_iters\":{warm_iters},\
                 \"speedup\":{:.2},\"tol\":{tol:e},\"n\":{},\"m\":{},\
                 \"warm_vs_cold_rmse\":{err:e}}}",
                cold_iters as f64 / warm_iters.max(1) as f64,
                train.n(),
                solver.m(),
            );
            std::fs::write(&path, json).expect("writing RETRAIN_BENCH_OUT");
            eprintln!("wrote retrain bench summary to {path}");
        }
    });
}
