//! Cross-module integration tests: the theorem-shaped guarantees of the
//! paper checked end-to-end through the public API, plus property-based
//! invariants via the in-repo mini-proptest (`util::prop`).

use bless::baselines::{exact_rls, uniform};
use bless::bless::{bless, bless_r, BlessConfig, BlessRConfig};
use bless::data::{auc, susy_like};
use bless::falkon::{nystrom_krr, Falkon};
use bless::kernels::{Gaussian, KernelEngine, NativeEngine};
use bless::leverage::{
    effective_dimension, exact_leverage_scores, LsGenerator, RAccStats, WeightedSet,
};
use bless::rng::Rng;
use bless::util::prop::for_all;

fn engine(n: usize, sigma: f64, seed: u64) -> NativeEngine {
    let ds = susy_like(n, &mut Rng::seeded(seed));
    NativeEngine::new(ds.x, Gaussian::new(sigma))
}

/// Eq. (2): BLESS and BLESS-R scores lie in a multiplicative band around
/// the exact scores for every point, at every path level we spot-check.
#[test]
fn thm1a_multiplicative_accuracy_band() {
    let eng = engine(500, 3.0, 1);
    let lambda = 2e-3;
    let all: Vec<usize> = (0..500).collect();
    let exact = exact_leverage_scores(&eng, lambda).unwrap();

    for (name, set) in [
        ("bless", bless(&eng, lambda, &BlessConfig::default(), &mut Rng::seeded(2))
            .final_set()
            .clone()),
        ("bless-r", bless_r(&eng, lambda, &BlessRConfig::default(), &mut Rng::seeded(3))
            .final_set()
            .clone()),
    ] {
        let gen = LsGenerator::new(&eng, &set, lambda).unwrap();
        let stats = RAccStats::from_scores(&gen.scores(&all), &exact);
        // practical-constant band (paper t with small q1/q2): [1/3, 3]
        assert!(stats.min > 1.0 / 3.5, "{name}: min ratio {}", stats.min);
        assert!(stats.max < 3.5, "{name}: max ratio {}", stats.max);
        assert!((stats.mean - 1.0).abs() < 0.5, "{name}: mean {}", stats.mean);
    }
}

/// Thm. 1(b): |J_h| = O(q₂ d_eff(λ_h)) along the whole path.
#[test]
fn thm1b_path_sizes_track_deff() {
    let eng = engine(600, 3.0, 4);
    let lambda = 1e-3;
    let cfg = BlessConfig::default();
    let path = bless(&eng, lambda, &cfg, &mut Rng::seeded(5));
    // spot-check three levels (exact d_eff is O(n³) per level)
    let levels = &path.levels;
    for l in [&levels[0], &levels[levels.len() / 2], levels.last().unwrap()] {
        let deff = effective_dimension(&exact_leverage_scores(&eng, l.lambda).unwrap());
        assert!(
            (l.set.len() as f64) <= 5.0 * cfg.q2 * deff + cfg.min_m as f64,
            "λ={}: |J|={} vs deff={deff}",
            l.lambda,
            l.set.len()
        );
    }
}

/// The whole-path property the paper advertises for cross-validation:
/// every level's generator is accurate *at its own λ_h*.
#[test]
fn path_levels_are_each_accurate() {
    let eng = engine(400, 3.0, 6);
    let path = bless(&eng, 2e-3, &BlessConfig::default(), &mut Rng::seeded(7));
    let all: Vec<usize> = (0..400).collect();
    // check the last three levels (most relevant λs)
    for l in path.levels.iter().rev().take(3) {
        let exact = exact_leverage_scores(&eng, l.lambda).unwrap();
        let gen = LsGenerator::new(&eng, &l.set, l.lambda).unwrap();
        let stats = RAccStats::from_scores(&gen.scores(&all), &exact);
        assert!(
            stats.mean > 0.5 && stats.mean < 2.0,
            "level λ={} mean R-ACC {}",
            l.lambda,
            stats.mean
        );
    }
}

/// FALKON-BLESS end-to-end beats (or matches) FALKON-UNI with the same
/// number of centers on held-out AUC — the Figure-4 claim in miniature.
#[test]
fn falkon_bless_competitive_with_uniform() {
    let mut rng = Rng::seeded(8);
    let ds = susy_like(1_500, &mut rng);
    let (train, test) = ds.split(0.3, &mut rng);
    let eng = NativeEngine::new(train.x.clone(), Gaussian::new(4.0));
    let lambda_b = 1e-3;
    let lambda_f = 1e-5;
    let path = bless(&eng, lambda_b, &BlessConfig::default(), &mut rng);
    let bset = path.final_set().clone();
    let m = bset.len();

    let bless_model = Falkon::new(&eng, &bset, lambda_f)
        .unwrap()
        .fit(&train.y, 12, None)
        .unwrap();
    let b_auc = auc(&bless_model.predict(&eng, &test.x), &test.y);

    let uni = WeightedSet::uniform(rng.sample_without_replacement(train.n(), m), lambda_f);
    let uni_model =
        Falkon::new(&eng, &uni, lambda_f).unwrap().fit(&train.y, 12, None).unwrap();
    let u_auc = auc(&uni_model.predict(&eng, &test.x), &test.y);

    assert!(b_auc > 0.75, "FALKON-BLESS AUC {b_auc}");
    assert!(b_auc >= u_auc - 0.03, "BLESS {b_auc} far below UNI {u_auc}");
}

/// Figure-1 structural claim, in the form that is robust at this scale:
/// the importance-weighted LS-sampled generator is *centered* (mean
/// R-ACC ≈ 1) while the unweighted uniform generator is systematically
/// biased away from 1 (it can only overestimate scores, and the bias
/// grows as λ shrinks) — i.e. uniform is the less faithful generator.
#[test]
fn uniform_generator_more_biased_than_exact_sampling() {
    let eng = engine(400, 3.0, 9);
    let lambda = 1e-3;
    let all: Vec<usize> = (0..400).collect();
    let exact = exact_leverage_scores(&eng, lambda).unwrap();
    let deff = effective_dimension(&exact);
    let m = ((2.0 * deff) as usize).min(350).max(40);

    let mean_racc = |set: &WeightedSet| {
        let gen = LsGenerator::new(&eng, set, lambda).unwrap();
        RAccStats::from_scores(&gen.scores(&all), &exact).mean
    };
    let (mut me_sum, mut mu_sum) = (0.0, 0.0);
    let reps = 5;
    for seed in 0..reps {
        let mut rng = Rng::seeded(10 + seed);
        me_sum += mean_racc(&exact_rls(&eng, lambda, m, &mut rng).set);
        mu_sum += mean_racc(&uniform(&eng, lambda, m, &mut rng).set);
    }
    let (me, mu) = (me_sum / reps as f64, mu_sum / reps as f64);
    assert!(
        (me - 1.0).abs() < (mu - 1.0).abs() + 0.05,
        "exact-LS mean {me} not closer to 1 than uniform mean {mu} (m={m}, deff={deff:.0})"
    );
    // uniform never *underestimates* at this m (its q05 stays ≥ ~1)
    let mut rng = Rng::seeded(99);
    let u = uniform(&eng, lambda, m, &mut rng).set;
    let gen = LsGenerator::new(&eng, &u, lambda).unwrap();
    let st = RAccStats::from_scores(&gen.scores(&all), &exact);
    assert!(st.q05 > 0.9, "uniform q05 {}", st.q05);
}

/// Property: Lemma 3 monotonicity holds for the *estimated* scores of any
/// weighted subset, not just exact ones.
#[test]
fn prop_lemma3_monotonicity_of_estimator() {
    let eng = engine(200, 3.0, 11);
    for_all(12, 0xBEEF, |g| {
        let lam = g.f64_log_in(1e-4..1e-1);
        let lam_p = lam * g.f64_in(1.5..10.0);
        let m = g.usize_in(5..40);
        let idx = g.rng().sample_without_replacement(200, m);
        let set = WeightedSet::uniform(idx, lam);
        let lo = LsGenerator::new(&eng, &set, lam_p).unwrap();
        let hi = LsGenerator::new(&eng, &set, lam).unwrap();
        let probe: Vec<usize> = (0..20).map(|i| i * 10).collect();
        let s_lo = lo.scores(&probe);
        let s_hi = hi.scores(&probe);
        for (a, b) in s_lo.iter().zip(&s_hi) {
            assert!(*a <= *b + 1e-12, "ℓ(λ') ≤ ℓ(λ) violated: {a} vs {b}");
            assert!(*b <= (lam_p / lam) * *a + 1e-9, "(λ'/λ) bound violated");
        }
    });
}

/// Property: FALKON prediction is linear in the training labels
/// (sanity of the whole solve path) and deterministic.
#[test]
fn prop_falkon_label_linearity() {
    let eng = engine(150, 3.0, 12);
    let centers: Vec<usize> = (0..30).map(|i| i * 5).collect();
    let lambda = 1e-3;
    for_all(6, 0xFACE, |g| {
        let y1: Vec<f64> = (0..150).map(|_| g.gaussian()).collect();
        let y2: Vec<f64> = (0..150).map(|_| g.gaussian()).collect();
        let a = g.f64_in(-2.0..2.0);
        let solve = |y: &[f64]| {
            nystrom_krr(&eng, &centers, lambda, y).unwrap().alpha
        };
        let s1 = solve(&y1);
        let s2 = solve(&y2);
        let combo: Vec<f64> = y1.iter().zip(&y2).map(|(u, v)| a * u + v).collect();
        let sc = solve(&combo);
        for i in 0..30 {
            let expect = a * s1[i] + s2[i];
            assert!(
                (sc[i] - expect).abs() < 1e-6 * expect.abs().max(1.0),
                "linearity broken at {i}: {} vs {expect}",
                sc[i]
            );
        }
    });
}

/// Property: every sampler returns valid weighted sets for random (n, λ).
#[test]
fn prop_all_samplers_valid_outputs() {
    for_all(8, 0xD00D, |g| {
        let n = g.usize_in(60..220);
        let lam = g.f64_log_in(1e-3..1e-1);
        let ds = susy_like(n, g.rng());
        let eng = NativeEngine::new(ds.x, Gaussian::new(g.f64_in(1.0..6.0)));
        for &m in bless::coordinator::Method::all() {
            let (set, _) =
                bless::coordinator::run_method(m, &eng, lam, 30.min(n), g.rng());
            set.validate().unwrap();
            assert!(set.indices.iter().all(|&i| i < n), "{:?} out of range", m);
            assert!(!set.is_empty());
        }
    });
}
