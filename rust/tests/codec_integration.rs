//! Artifact-codec acceptance tests at serving scale (ISSUE 2): for an
//! M=2000 model the binary artifact must be substantially smaller and
//! dramatically faster to load than JSON, while roundtripping every
//! `f64` bit-exactly through either encoding.

use bless::linalg::Matrix;
use bless::rng::Rng;
use bless::serve::{codec, Format, ModelArtifact, Predictor};
use std::time::Instant;

/// Full-mantissa (trained-weight-like) values: the honest worst case
/// for both encodings — nothing here compresses by accident.
fn big_artifact(m: usize, d: usize) -> ModelArtifact {
    let mut rng = Rng::seeded(4242);
    ModelArtifact {
        sigma: 4.0,
        centers: Matrix::from_fn(m, d, |_, _| rng.gaussian()),
        alpha: (0..m).map(|_| rng.gaussian() * 1e-3).collect(),
        trained_n: m * 4,
        dataset: "codec-it".to_string(),
    }
}

fn best_secs(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

#[test]
fn m2000_binary_artifact_is_smaller_and_much_faster_to_load() {
    let art = big_artifact(2_000, 18);
    let dir = std::env::temp_dir();
    let json_path = dir.join(format!("bless-codec-it-{}.json", std::process::id()));
    let bin_path = dir.join(format!("bless-codec-it-{}.bin", std::process::id()));
    art.save_as(&json_path, Format::Json).unwrap();
    art.save_as(&bin_path, Format::Binary).unwrap();

    let json_bytes = std::fs::metadata(&json_path).unwrap().len();
    let bin_bytes = std::fs::metadata(&bin_path).unwrap().len();
    // raw 8-byte f64 sections vs ~20 bytes of shortest-roundtrip decimal
    // per value: the binary artifact must be at least 2× smaller (in
    // practice ~2.5×, the information-theoretic ceiling for bit-exact
    // full-mantissa payloads)
    assert!(
        json_bytes >= 2 * bin_bytes,
        "binary not smaller: {bin_bytes} B binary vs {json_bytes} B JSON"
    );

    let json_load = best_secs(3, || {
        ModelArtifact::load(&json_path).unwrap();
    });
    let bin_load = best_secs(3, || {
        ModelArtifact::load(&bin_path).unwrap();
    });
    assert!(
        json_load >= 5.0 * bin_load,
        "binary load not ≥5× faster: {:.2} ms JSON vs {:.2} ms binary",
        json_load * 1e3,
        bin_load * 1e3
    );
    println!(
        "M=2000: size {json_bytes}/{bin_bytes} B ({:.2}×), load {:.1}/{:.2} ms ({:.0}×)",
        json_bytes as f64 / bin_bytes as f64,
        json_load * 1e3,
        bin_load * 1e3,
        json_load / bin_load
    );

    // both loaded artifacts are bit-identical to the original and to
    // each other, and so are their predictions
    let via_json = ModelArtifact::load(&json_path).unwrap();
    let via_bin = ModelArtifact::load(&bin_path).unwrap();
    std::fs::remove_file(&json_path).ok();
    std::fs::remove_file(&bin_path).ok();
    for (a, b) in art.alpha.iter().zip(&via_bin.alpha) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    for ((a, b), c) in art
        .centers
        .as_slice()
        .iter()
        .zip(via_bin.centers.as_slice())
        .zip(via_json.centers.as_slice())
    {
        assert_eq!(a.to_bits(), b.to_bits());
        assert_eq!(b.to_bits(), c.to_bits());
    }

    let q = Matrix::from_fn(5, 18, |i, j| ((i * 18 + j) as f64 * 0.19).sin());
    let p_json = Predictor::new(&via_json).predict_batch(&q).unwrap();
    let p_bin = Predictor::new(&via_bin).predict_batch(&q).unwrap();
    for (a, b) in p_json.iter().zip(&p_bin) {
        assert_eq!(a.to_bits(), b.to_bits(), "codec paths diverge: {a} vs {b}");
    }
}

#[test]
fn m2000_binary_roundtrips_through_memory_bit_exactly() {
    let art = big_artifact(2_000, 18);
    let bytes = codec::encode(&art);
    let back = codec::decode(&bytes).unwrap();
    assert_eq!(back.m(), 2_000);
    assert_eq!(back.d(), 18);
    for (a, b) in art.centers.as_slice().iter().zip(back.centers.as_slice()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    for (a, b) in art.alpha.iter().zip(&back.alpha) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
