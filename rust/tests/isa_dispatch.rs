//! Accuracy gates for the runtime-dispatched micro-kernel tier.
//!
//! The dispatch contract (`linalg::dispatch`) allows results to vary
//! **by ISA** but only within documented bounds against the scalar
//! reference. This suite enforces those bounds on an AVX2 host and
//! degrades to a no-op (beyond the scalar self-checks) elsewhere:
//!
//! * the vectorized exponential stays within 4 ULP of `f64::exp` over
//!   the kernel-relevant domain `[-708, 0]`, and flushes below it;
//! * GEMM / SYRK / matvec products agree between backends to a tight
//!   relative tolerance at sizes that straddle the register-tile and
//!   cache-block boundaries (4×8 tiles, NB = 96, MC = 64);
//! * the blocked Cholesky factors the same SPD matrix to matching `L`
//!   under both backends.
//!
//! Tests serialize on a file-local mutex: the active ISA is a process
//! global, so concurrent flips would bleed between tests.

use bless::linalg::{self, MatMul, Matrix};
use std::sync::{Mutex, MutexGuard};

static ISA_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    ISA_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` under `isa`, restoring auto-detection afterwards. `None`
/// when the host cannot execute that backend.
fn under_isa<T>(isa: linalg::Isa, f: impl FnOnce() -> T) -> Option<T> {
    if linalg::set_isa(isa).is_err() {
        return None;
    }
    let out = f();
    linalg::set_isa_from_str("auto").unwrap();
    Some(out)
}

fn ulp_diff(a: f64, b: f64) -> u64 {
    // both operands are non-negative finite here, so the bit patterns
    // order the same way the values do
    (a.to_bits() as i64 - b.to_bits() as i64).unsigned_abs()
}

fn max_rel_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs() / x.abs().max(1.0))
        .fold(0.0, f64::max)
}

fn test_matrix(rows: usize, cols: usize, seed: f64) -> Matrix {
    Matrix::from_fn(rows, cols, |i, j| {
        (seed + i as f64 * 0.7310 + j as f64 * 0.2913).sin() * 0.5
    })
}

#[test]
fn vexp_stays_within_4_ulp_of_f64_exp() {
    let _g = lock();
    let run = under_isa(linalg::Isa::Avx2, || {
        let kern = linalg::kernels();
        // dense sweep of the documented domain [-708, 0]: gamma = 1,
        // ai = 0 and a zero row turn exp_row into x ↦ exp(-b_sq)
        const N: usize = 200_000;
        let b_sq: Vec<f64> = (0..N).map(|j| 708.0 * j as f64 / (N - 1) as f64).collect();
        let mut row = vec![0.0; N];
        (kern.exp_row)(1.0, 0.0, &b_sq, &mut row);
        let mut worst = 0u64;
        for (got, &d2) in row.iter().zip(&b_sq) {
            let want = (-d2).exp();
            worst = worst.max(ulp_diff(*got, want));
        }
        assert!(worst <= 4, "vexp drifted to {worst} ULP from f64::exp");

        // endpoints: exp(0) is exact, −708 still computes, below flushes
        let b_sq = [0.0, 708.0, 708.0000001, 710.0, 1.0e6];
        let mut row = [0.0; 5];
        (kern.exp_row)(1.0, 0.0, &b_sq, &mut row);
        assert_eq!(row[0], 1.0, "exp(0) must be exact");
        assert!(row[1] > 0.0, "exp(-708) is still a normal number");
        assert_eq!(row[2], 0.0, "inputs below -708 flush to zero");
        assert_eq!(row[3], 0.0);
        assert_eq!(row[4], 0.0);
    });
    if run.is_none() {
        eprintln!("skipping: no AVX2+FMA on this host");
    }
}

#[test]
fn exp_row_backends_agree_on_gaussian_kernel_rows() {
    let _g = lock();
    // realistic kernel-pass inputs: nonzero ai/b_sq/inner-product rows,
    // odd length so the vector body and scalar tail both execute
    const COLS: usize = 1003;
    let gamma = 0.37;
    let a_sq = 1.9;
    let b_sq: Vec<f64> = (0..COLS).map(|j| 2.0 + (j as f64 * 0.113).sin()).collect();
    let base: Vec<f64> = (0..COLS).map(|j| (j as f64 * 0.071).cos() * 0.8).collect();

    let run = |isa| {
        under_isa(isa, || {
            let kern = linalg::kernels();
            let mut row = base.clone();
            (kern.exp_row)(gamma, a_sq, &b_sq, &mut row);
            row
        })
    };
    let scalar = run(linalg::Isa::Scalar).expect("scalar backend always available");
    let Some(avx2) = run(linalg::Isa::Avx2) else {
        eprintln!("skipping: no AVX2+FMA on this host");
        return;
    };
    // the squared-distance arithmetic is bit-identical between the
    // backends (2·v is exact, FNMADD rounds once like the scalar
    // subtraction), so the whole gap is the ≤ 4 ULP exp bound
    for (s, v) in scalar.iter().zip(&avx2) {
        assert!(ulp_diff(*s, *v) <= 8, "kernel row drifted: {s} vs {v}");
    }
}

#[test]
fn gemm_and_syrk_backends_agree_at_block_straddling_sizes() {
    let _g = lock();
    // (m, k, n) chosen to straddle the 4×8 register tile, the KC = 256
    // panel and the NB = 96 / MC = 64 cache blocks
    for &(m, k, n) in &[(5, 9, 11), (65, 97, 129), (96, 256, 95), (33, 300, 64)] {
        let a = test_matrix(m, k, 0.1);
        let b = test_matrix(k, n, 0.2);
        let bt = test_matrix(n, k, 0.3);

        let run = |isa| {
            under_isa(isa, || {
                let nn = linalg::gemm(&a, &b);
                let nt = MatMul::nt().run(&a, &bt);
                let tn = MatMul::tn().run(&b, &b);
                let lower = MatMul::tn().lower().run(&a, &a);
                let syrk = linalg::syrk(&a);
                let mut mv = vec![0.0; m];
                linalg::matvec_into(&a, &b.col(0), &mut mv);
                (nn, nt, tn, lower, syrk, mv)
            })
        };
        let s = run(linalg::Isa::Scalar).expect("scalar backend always available");
        let Some(v) = run(linalg::Isa::Avx2) else {
            eprintln!("skipping: no AVX2+FMA on this host");
            return;
        };
        let gate = |tag: &str, x: &Matrix, y: &Matrix| {
            let err = max_rel_err(x.as_slice(), y.as_slice());
            assert!(err < 1e-12, "{tag} @ {m}x{k}x{n}: rel err {err:.3e}");
        };
        gate("gemm_nn", &s.0, &v.0);
        gate("gemm_nt", &s.1, &v.1);
        gate("gemm_tn", &s.2, &v.2);
        gate("syrk_tn_lower", &s.3, &v.3);
        gate("syrk_nt", &s.4, &v.4);
        let err = max_rel_err(&s.5, &v.5);
        assert!(err < 1e-12, "matvec @ {m}x{k}: rel err {err:.3e}");
    }
}

#[test]
fn cholesky_and_solves_backends_agree() {
    let _g = lock();
    // NB = 96 and the MC = 64 panel both straddled
    for &n in &[31usize, 95, 97, 160] {
        let m = test_matrix(n, n + 7, 0.4);
        let mut spd = linalg::syrk(&m);
        for i in 0..n {
            spd.set(i, i, spd.get(i, i) + n as f64);
        }
        let rhs: Vec<f64> = (0..n).map(|i| (i as f64 * 0.17).cos()).collect();

        let run = |isa| {
            under_isa(isa, || {
                let chol = linalg::cholesky(&spd).expect("SPD by construction");
                let x = chol.solve(&rhs);
                (chol.l().clone(), x)
            })
        };
        let s = run(linalg::Isa::Scalar).expect("scalar backend always available");
        let Some(v) = run(linalg::Isa::Avx2) else {
            eprintln!("skipping: no AVX2+FMA on this host");
            return;
        };
        let err = max_rel_err(s.0.as_slice(), v.0.as_slice());
        assert!(err < 1e-11, "cholesky L @ n={n}: rel err {err:.3e}");
        let err = max_rel_err(&s.1, &v.1);
        assert!(err < 1e-9, "llt solve @ n={n}: rel err {err:.3e}");
    }
}

#[test]
fn isa_override_api_round_trips() {
    let _g = lock();
    // scalar is always selectable
    linalg::set_isa(linalg::Isa::Scalar).unwrap();
    assert_eq!(linalg::active_isa(), linalg::Isa::Scalar);
    assert_eq!(linalg::kernels().isa, linalg::Isa::Scalar);
    // unknown strings are rejected without changing the active backend
    assert!(linalg::set_isa_from_str("sse9").is_err());
    assert_eq!(linalg::active_isa(), linalg::Isa::Scalar);
    // auto re-detects (and is what every other test restores)
    linalg::set_isa_from_str("auto").unwrap();
    let detected = linalg::active_isa();
    assert!(linalg::set_isa(detected).is_ok(), "detected ISA must be selectable");
    linalg::set_isa_from_str("auto").unwrap();
}
