//! Artifact damage recovery (ISSUE 9): every way an artifact file can
//! be torn — truncation, bit rot, zero length, a crash between
//! temp-stage and rename — must surface as a clean typed error from
//! `ModelArtifact::load`, never a panic, a hang, or a silently wrong
//! model. The binary codec's trailing checksum and the JSON parser's
//! strictness are what make this hold.
//!
//! The chaos harness's `artifact.corrupt` point is also exercised here:
//! with it armed, loads of a *good* file see deterministically damaged
//! bytes and must fail just as cleanly. Tests serialize on a lock
//! because the fault registry is process-global.

mod common;

use bless::falkon::{ckpt, CgState};
use bless::faults::{self, FaultPlan, FaultPoint, FaultRule};
use bless::linalg::Matrix;
use bless::serve::ModelArtifact;
use common::with_timeout;
use std::path::PathBuf;
use std::sync::{Mutex, OnceLock};

/// All tests here load artifacts; the fault-armed one must not overlap
/// with the rest (corruption is process-global while armed).
fn faults_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

fn artifact() -> ModelArtifact {
    ModelArtifact {
        sigma: 2.0,
        centers: Matrix::from_fn(6, 4, |i, j| ((i * 4 + j) as f64 * 0.23).cos()),
        alpha: (0..6).map(|i| 0.1 * (i as f64 + 1.0)).collect(),
        trained_n: 6,
        dataset: "recovery".to_string(),
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("bless-artrec-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Assert a load fails as a *clean* error: an `Err` with a non-empty
/// message (reaching here at all means no panic and no hang).
fn assert_clean_error(path: &std::path::Path, what: &str) {
    match ModelArtifact::load(path) {
        Ok(_) => panic!("{what}: damaged artifact loaded as if valid"),
        Err(e) => {
            let msg = e.to_string();
            assert!(!msg.is_empty(), "{what}: error must carry a message");
        }
    }
}

#[test]
fn truncated_artifacts_fail_cleanly_in_both_codecs() {
    let _g = faults_lock().lock().unwrap_or_else(|e| e.into_inner());
    with_timeout(60, || {
        let dir = tmp_dir("trunc");
        for ext in ["bless", "json"] {
            let path = dir.join(format!("model.{ext}"));
            artifact().save(&path).unwrap();
            let full = std::fs::read(&path).unwrap();
            assert!(ModelArtifact::load(&path).is_ok(), "pristine {ext} must load");
            // a short read at several depths, including cutting the
            // binary checksum trailer off
            for keep in [full.len() - 1, full.len() / 2, 16, 1] {
                std::fs::write(&path, &full[..keep]).unwrap();
                assert_clean_error(&path, &format!(".{ext} truncated to {keep} bytes"));
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    });
}

#[test]
fn bit_flips_and_zero_length_files_fail_cleanly() {
    let _g = faults_lock().lock().unwrap_or_else(|e| e.into_inner());
    with_timeout(60, || {
        let dir = tmp_dir("bits");
        // binary: the FNV trailer catches a flip anywhere in the payload
        let bin = dir.join("model.bless");
        artifact().save(&bin).unwrap();
        let full = std::fs::read(&bin).unwrap();
        for idx in [8, full.len() / 2, full.len() - 1] {
            let mut bytes = full.clone();
            bytes[idx] ^= 0x10;
            std::fs::write(&bin, &bytes).unwrap();
            assert_clean_error(&bin, &format!(".bless bit flip at byte {idx}"));
        }
        // json: structural damage (the leading brace) must parse-error
        let json = dir.join("model.json");
        artifact().save(&json).unwrap();
        let mut bytes = std::fs::read(&json).unwrap();
        bytes[0] ^= 0x01;
        std::fs::write(&json, &bytes).unwrap();
        assert_clean_error(&json, ".json corrupted opening brace");
        // zero length, either extension
        for ext in ["bless", "json"] {
            let path = dir.join(format!("empty.{ext}"));
            std::fs::write(&path, b"").unwrap();
            assert_clean_error(&path, &format!("zero-length .{ext}"));
        }
        std::fs::remove_dir_all(&dir).ok();
    });
}

/// A crash between temp-stage and rename leaves a stale `.tmp-…` file
/// and an untouched (or absent) destination — loaders must never pick
/// the temp up, and the next save must still land atomically.
#[test]
fn mid_rename_crash_leaves_loads_and_resaves_working() {
    let _g = faults_lock().lock().unwrap_or_else(|e| e.into_inner());
    with_timeout(60, || {
        let dir = tmp_dir("rename");
        let path = dir.join("model.bless");

        // crash BEFORE the first rename: only the torn temp exists
        std::fs::write(dir.join(".model.bless.tmp-4242-0"), b"torn half-written").unwrap();
        assert_clean_error(&path, "destination missing, only a stale temp present");

        // a good save lands despite the stale temp sitting there
        artifact().save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();
        assert!(ModelArtifact::load(&path).is_ok());

        // crash between stage and rename on a RE-save: the destination
        // still holds the complete previous bytes
        std::fs::write(dir.join(".model.bless.tmp-4242-1"), &good[..good.len() / 3]).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), good, "destination must be untouched");
        let reloaded = ModelArtifact::load(&path).unwrap();
        assert_eq!(reloaded.m(), 6);
        std::fs::remove_dir_all(&dir).ok();
    });
}

/// With `artifact.corrupt` armed at p=1, every load of a good binary
/// artifact sees damaged bytes — and the checksum turns each into a
/// clean error, deterministically for a fixed seed.
#[test]
fn injected_corruption_on_load_fails_cleanly_and_replays() {
    let _g = faults_lock().lock().unwrap_or_else(|e| e.into_inner());
    with_timeout(60, || {
        let dir = tmp_dir("inject");
        let path = dir.join("model.bless");
        artifact().save(&path).unwrap();

        let plan = FaultPlan::seeded(0xBAD)
            .with(FaultPoint::ArtifactCorrupt, FaultRule { p: 1.0, ms: 0 });
        faults::configure(Some(plan.clone()));
        let first: Vec<String> = (0..8)
            .map(|i| {
                ModelArtifact::load(&path)
                    .expect_err(&format!("corrupted load {i} must fail"))
                    .to_string()
            })
            .collect();
        // same seed → the same 8 corruptions → the same 8 errors
        faults::configure(Some(plan));
        let second: Vec<String> =
            (0..8).map(|_| ModelArtifact::load(&path).unwrap_err().to_string()).collect();
        assert_eq!(first, second, "corruption must replay deterministically");
        faults::configure(None);

        // disarmed, the untouched file loads fine — corruption happened
        // in memory, never on disk
        assert!(ModelArtifact::load(&path).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    });
}

// ---- BLESSCKPT checkpoints -------------------------------------------
//
// The same damage classes, applied to the mid-fit CG checkpoint codec.
// The contract differs in one way: a damaged *checkpoint* is not fatal —
// `ckpt::load` degrades to `None` (cold start) with a stderr warning,
// because the fit can always start over. It must still never panic,
// hang, or hand back a wrong state.

fn cg_state() -> CgState {
    CgState {
        x: (0..10).map(|i| (i as f64 * 0.31).sin()).collect(),
        r: (0..10).map(|i| (i as f64 * 0.17).cos()).collect(),
        p: (0..10).map(|i| i as f64 * 0.5 - 2.0).collect(),
        iter: 6,
        rs_old: 3.7e-4,
    }
}

const FP: u64 = 0xC0FFEE;

#[test]
fn damaged_checkpoints_cold_start_instead_of_resuming() {
    let _g = faults_lock().lock().unwrap_or_else(|e| e.into_inner());
    with_timeout(60, || {
        let dir = tmp_dir("ckpt-damage");
        let path = dir.join("fit.ckpt");
        ckpt::save(&path, &cg_state(), FP).unwrap();
        let full = std::fs::read(&path).unwrap();
        assert_eq!(ckpt::load(&path, FP), Some(cg_state()), "pristine checkpoint must resume");

        // truncation at several depths, including cutting only the
        // checksum trailer and leaving a single magic byte
        for keep in [full.len() - 1, full.len() / 2, 16, 1] {
            std::fs::write(&path, &full[..keep]).unwrap();
            assert_eq!(ckpt::load(&path, FP), None, "truncated to {keep} bytes must cold-start");
        }
        // a single flipped bit anywhere — header, payload, trailer
        for idx in [9, 30, full.len() / 2, full.len() - 1] {
            let mut bytes = full.clone();
            bytes[idx] ^= 0x20;
            std::fs::write(&path, &bytes).unwrap();
            assert_eq!(ckpt::load(&path, FP), None, "bit flip at byte {idx} must cold-start");
        }
        // zero length and wrong-codec magic (a model artifact is not a
        // checkpoint, even though both carry FNV trailers)
        std::fs::write(&path, b"").unwrap();
        assert_eq!(ckpt::load(&path, FP), None);
        artifact().save_as(&path, bless::serve::Format::Binary).unwrap();
        assert_eq!(ckpt::load(&path, FP), None, "BLESSBIN bytes must not decode as BLESSCKPT");

        // intact file, foreign fit: the fingerprint gate must refuse it
        ckpt::save(&path, &cg_state(), FP).unwrap();
        assert_eq!(ckpt::load(&path, FP ^ 1), None, "foreign fingerprint must cold-start");
        std::fs::remove_dir_all(&dir).ok();
    });
}

/// A crash between the checkpoint's temp-stage and rename leaves a stale
/// `.tmp-…` file; resume must ignore it (missing destination → silent
/// cold start) and the next save must still land atomically beside it.
#[test]
fn stale_checkpoint_temps_are_ignored_and_do_not_block_saves() {
    let _g = faults_lock().lock().unwrap_or_else(|e| e.into_inner());
    with_timeout(60, || {
        let dir = tmp_dir("ckpt-rename");
        let path = dir.join("fit.ckpt");
        std::fs::write(dir.join(".fit.ckpt.tmp-4242-0"), b"torn half-written state").unwrap();
        assert_eq!(ckpt::load(&path, FP), None, "only a stale temp present → cold start");

        ckpt::save(&path, &cg_state(), FP).unwrap();
        assert_eq!(ckpt::load(&path, FP), Some(cg_state()));

        // re-save crash: destination keeps the previous complete bytes
        let good = std::fs::read(&path).unwrap();
        std::fs::write(dir.join(".fit.ckpt.tmp-4242-1"), &good[..good.len() / 3]).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), good, "destination must be untouched");
        assert_eq!(ckpt::load(&path, FP), Some(cg_state()));
        std::fs::remove_dir_all(&dir).ok();
    });
}

/// With `ckpt.corrupt` armed at p=1, every load of a good checkpoint
/// sees mutilated bytes in memory and cold-starts cleanly; the same seed
/// replays the same mutilations, and disarming restores the resume.
#[test]
fn injected_ckpt_corruption_cold_starts_and_replays() {
    let _g = faults_lock().lock().unwrap_or_else(|e| e.into_inner());
    with_timeout(60, || {
        let dir = tmp_dir("ckpt-inject");
        let path = dir.join("fit.ckpt");
        ckpt::save(&path, &cg_state(), FP).unwrap();
        let pristine = std::fs::read(&path).unwrap();

        let plan = FaultPlan::seeded(0xC4A0)
            .with(FaultPoint::CkptCorrupt, FaultRule { p: 1.0, ms: 0 });
        faults::configure(Some(plan.clone()));
        for i in 0..8 {
            assert_eq!(ckpt::load(&path, FP), None, "corrupted load {i} must cold-start");
        }
        // determinism: re-arming the same seed mutilates the bytes the
        // same way, call for call
        faults::configure(Some(plan.clone()));
        let first: Vec<Vec<u8>> = (0..4)
            .map(|_| {
                let mut b = pristine.clone();
                faults::corrupt_checkpoint(&mut b);
                b
            })
            .collect();
        faults::configure(Some(plan));
        let second: Vec<Vec<u8>> = (0..4)
            .map(|_| {
                let mut b = pristine.clone();
                faults::corrupt_checkpoint(&mut b);
                b
            })
            .collect();
        assert_eq!(first, second, "ckpt.corrupt must replay deterministically");
        assert!(first.iter().all(|b| *b != pristine), "armed at p=1, every load is damaged");
        faults::configure(None);

        // disarmed, the on-disk file was never touched — resume works
        assert_eq!(ckpt::load(&path, FP), Some(cg_state()));
        std::fs::remove_dir_all(&dir).ok();
    });
}
