//! `serve --stats-flush-secs N` end-to-end: periodic stats snapshots
//! bound what a hard kill can lose (satellite of ISSUE 10).
//!
//! Without periodic flushing, `--stats-file` only persists counters on
//! *graceful* shutdown — a SIGKILL loses the whole run. Here we spawn
//! the real `repro serve` binary with a sub-second flush period, drive
//! traffic, SIGKILL it mid-flight, and verify a restarted server folds
//! the flushed counters back in and keeps counting on top of them.

mod common;

use bless::linalg::Matrix;
use bless::serve::registry::{ModelSpec, Registry, RegistryConfig};
use bless::serve::{self, stats_io, Client, ModelArtifact, ServeConfig};
use common::with_timeout;
use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

fn artifact() -> ModelArtifact {
    ModelArtifact {
        sigma: 1.5,
        centers: Matrix::from_fn(4, 3, |i, j| ((i * 3 + j) as f64 * 0.31).cos()),
        alpha: vec![0.4, -0.2, 0.9, 0.1],
        trained_n: 4,
        dataset: "flush".to_string(),
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("bless-statsflush-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// SIGKILLs the child if the test panics before doing so itself, so a
/// failed assertion cannot leak a serving process.
struct KillOnDrop(Child);
impl Drop for KillOnDrop {
    fn drop(&mut self) {
        self.0.kill().ok();
        self.0.wait().ok();
    }
}

/// How many requests the flushed stats file currently records for
/// `default`. Loads into a *fresh* registry each call because
/// [`stats_io::load`] folds counters additively.
fn flushed_requests(path: &std::path::Path) -> Option<u64> {
    let reg = Registry::new(
        vec![ModelSpec { name: "default".to_string(), artifact: artifact(), source: None }],
        RegistryConfig::default(),
    )
    .unwrap();
    stats_io::load(path, &reg).ok()?;
    Some(reg.get("default").unwrap().stats.snapshot().requests)
}

#[test]
fn periodic_flush_survives_a_hard_kill_and_restart() {
    with_timeout(180, || {
        let dir = tmp_dir("kill");
        let model_path = dir.join("model.bin");
        let stats_path = dir.join("stats.json");
        artifact().save(&model_path).unwrap();

        let child = Command::new(env!("CARGO_BIN_EXE_repro"))
            .args([
                "serve",
                "--model",
                model_path.to_str().unwrap(),
                "--port",
                "0",
                "--workers",
                "1",
                "--stats-file",
                stats_path.to_str().unwrap(),
                "--stats-flush-secs",
                "0.2",
            ])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning repro serve");
        let mut child = KillOnDrop(child);

        // the server announces its ephemeral port on stdout
        let mut lines = BufReader::new(child.0.stdout.take().unwrap());
        let addr = loop {
            let mut line = String::new();
            if lines.read_line(&mut line).expect("reading child stdout") == 0 {
                panic!("child exited before announcing its address");
            }
            if let Some(rest) = line.strip_prefix("listening on ") {
                break rest.split_whitespace().next().unwrap().to_string();
            }
        };

        let sent = 40u64;
        let mut client = Client::connect(addr.as_str()).expect("connecting to child server");
        for i in 0..sent {
            let x: Vec<f64> = (0..3).map(|j| 0.1 * (i + j) as f64 - 0.5).collect();
            let (y, _) = client.predict(i, &x).expect("predict against child");
            assert!(y.is_finite());
        }

        // within a flush period or two, the stats file must have caught
        // up with everything we sent — that is the loss bound
        let t0 = Instant::now();
        loop {
            if flushed_requests(&stats_path).is_some_and(|r| r >= sent) {
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(60),
                "stats file never reflected {sent} requests (got {:?})",
                flushed_requests(&stats_path)
            );
            std::thread::sleep(Duration::from_millis(25));
        }

        // hard kill: no graceful-shutdown save, the periodic flush is
        // all that survives
        child.0.kill().expect("SIGKILL child");
        child.0.wait().expect("reaping child");

        // a restarted server folds the flushed counters back in…
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .workers(1)
            .stats_file(&stats_path)
            .build()
            .unwrap();
        let handle = serve::start(artifact(), &cfg).unwrap();
        let restored = handle.model_stats("default").expect("default registered").requests;
        assert!(
            restored >= sent,
            "restart restored {restored} requests, expected at least {sent}"
        );

        // …and keeps counting on top of the restored base
        let extra = 8u64;
        let mut client = Client::connect(handle.addr()).unwrap();
        for i in 0..extra {
            let x: Vec<f64> = (0..3).map(|j| 0.05 * (i + j) as f64).collect();
            let (y, _) = client.predict(1_000 + i, &x).unwrap();
            assert!(y.is_finite());
        }
        let now = handle.model_stats("default").unwrap().requests;
        assert!(
            now >= restored + extra,
            "counters must continue from the restored base ({now} < {restored} + {extra})"
        );
        handle.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    });
}

/// The CLI refuses a flush period with nowhere to flush to, loudly and
/// before binding anything.
#[test]
fn flush_without_a_stats_file_is_rejected_at_startup() {
    with_timeout(60, || {
        let dir = tmp_dir("reject");
        let model_path = dir.join("model.bin");
        artifact().save(&model_path).unwrap();
        let out = Command::new(env!("CARGO_BIN_EXE_repro"))
            .args([
                "serve",
                "--model",
                model_path.to_str().unwrap(),
                "--port",
                "0",
                "--stats-flush-secs",
                "1",
            ])
            .output()
            .expect("running repro serve");
        assert!(!out.status.success(), "serve must refuse --stats-flush-secs without --stats-file");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(
            err.contains("stats_flush requires a stats_file"),
            "unexpected error output: {err}"
        );
        std::fs::remove_dir_all(&dir).ok();
    });
}
