//! Property-test tier gating the leverage-score **estimator family**
//! (ISSUE 8): every approximate estimator — BLESS, RRLS, count-sketch,
//! SRFT, recursive-RLS Nyström — is held against the exact scores at
//! small `n` and fixed seeds, under **both** micro-kernel backends
//! (scalar + AVX2 where the host supports it; CI additionally re-runs
//! the whole binary with `BLESS_ISA=scalar`). Alongside the accuracy
//! gates: monotone improvement in the sketch size, seed-sensitivity
//! (same seed ⇒ bitwise-identical, distinct seeds ⇒ different but still
//! inside the gate), per-ISA property checks of the blocked Householder
//! QR behind the sketched solves, and regressions for the typed
//! [`LeverageError`] that replaced the old factorization panic.
//!
//! Tests here flip the process-global ISA selection, so they serialize
//! through one mutex (same scheme as `tests/parallel_determinism.rs`).

use bless::data::susy_like;
use bless::kernels::{Gaussian, NativeEngine};
use bless::leverage::{
    exact_leverage_scores, parse_estimator, run_estimator, LeverageError, LsGenerator,
    RAccStats, WeightedSet,
};
use bless::linalg::{self, qr, MatMul, Matrix};
use bless::rng::Rng;
use bless::util::prop::check_seed_sensitivity;
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Serialize tests that flip the global ISA selection.
fn lock() -> MutexGuard<'static, ()> {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    GATE.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` under every micro-kernel backend this host supports — always
/// scalar, plus AVX2 where available — then restore auto-detection.
fn for_each_isa(f: impl Fn(linalg::Isa)) {
    for isa in [linalg::Isa::Scalar, linalg::Isa::Avx2] {
        if linalg::set_isa(isa).is_ok() {
            f(isa);
        }
    }
    linalg::set_isa_from_str("auto").unwrap();
}

fn engine(n: usize, seed: u64) -> NativeEngine {
    let ds = susy_like(n, &mut Rng::seeded(seed));
    NativeEngine::new(ds.x, Gaussian::new(2.5))
}

/// Mean absolute relative error of `approx` against `exact`.
fn rel_err(approx: &[f64], exact: &[f64]) -> f64 {
    assert_eq!(approx.len(), exact.len());
    let s: f64 = approx.iter().zip(exact).map(|(a, e)| (a - e).abs() / e.max(1e-300)).sum();
    s / exact.len() as f64
}

/// Every approximate family member must land inside a multiplicative
/// R-ACC gate against the exact reference, at a fixed seed, per ISA.
/// The exact member must reproduce the reference to float roundoff.
#[test]
fn every_estimator_passes_the_accuracy_gate_per_isa() {
    let _g = lock();
    let eng = engine(220, 5);
    let lambda = 1e-2;
    // (spec, lower, upper) — multiplicative gates on the mean score
    // ratio; sketches at these sizes are near-exact, samplers looser.
    let gates = [
        ("bless", 0.5, 2.0),
        ("rrls", 0.5, 2.0),
        ("count-sketch:1024", 0.6, 1.7),
        ("srft:192", 0.6, 1.7),
        ("rls-nystrom:128", 0.4, 2.5),
    ];
    for_each_isa(|isa| {
        let exact = exact_leverage_scores(&eng, lambda).unwrap();
        // the exact family member IS the reference
        let e = parse_estimator("exact").unwrap();
        let out = run_estimator(e.as_ref(), &eng, lambda, &mut Rng::seeded(1)).unwrap();
        let stats = RAccStats::from_scores(&out.scores, &exact);
        assert!(stats.within_bound(1e-9), "exact vs itself ({}): {stats:?}", isa.name());
        assert!(out.kernel_evals >= (220 * 220) as u64, "exact evals not metered");

        for &(spec, lo, hi) in &gates {
            let est = parse_estimator(spec).expect(spec);
            let out = run_estimator(est.as_ref(), &eng, lambda, &mut Rng::seeded(12)).unwrap();
            assert_eq!(out.scores.len(), 220, "{spec}: wrong length");
            assert!(
                out.scores.iter().all(|&v| v.is_finite() && v > 0.0),
                "{spec} ({}): non-finite or non-positive scores",
                isa.name()
            );
            // the sketched estimators additionally clamp to ℓ ≤ 1
            if spec.starts_with("count-sketch") || spec.starts_with("srft") {
                assert!(out.scores.iter().all(|&v| v <= 1.0), "{spec}: score above 1");
            }
            let stats = RAccStats::from_scores(&out.scores, &exact);
            assert!(
                stats.mean > lo && stats.mean < hi,
                "{spec} ({}): mean R-ACC {} outside ({lo}, {hi})",
                isa.name(),
                stats.mean
            );
            assert!(out.kernel_evals > 0, "{spec}: kernel evals not metered");
            assert!(out.peak_bytes > 0, "{spec}: no workspace accounted");
        }
    });
}

/// At `s = p` (full subsample of the padded dimension) the SRFT's test
/// matrix is orthonormal, so the sketched scores equal the exact ones up
/// to float — the tight anchor of the sketching math, per ISA.
#[test]
fn srft_full_sketch_is_near_exact_per_isa() {
    let _g = lock();
    let eng = engine(64, 9); // power of two: p = n, no padding
    let lambda = 2e-2;
    for_each_isa(|isa| {
        let exact = exact_leverage_scores(&eng, lambda).unwrap();
        let est = parse_estimator("srft:64").unwrap();
        let approx = est.scores(&eng, lambda, &mut Rng::seeded(3)).unwrap();
        let stats = RAccStats::from_scores(&approx, &exact);
        assert!(
            stats.within_bound(1e-4),
            "orthonormal SRFT not exact under {}: {stats:?}",
            isa.name()
        );
    });
}

/// Growing the sketch must (on average over seeds) shrink the error —
/// the size knob is live, not cosmetic.
#[test]
fn sketch_error_improves_with_sketch_size() {
    let _g = lock();
    let eng = engine(200, 21);
    let lambda = 2e-2;
    let exact = exact_leverage_scores(&eng, lambda).unwrap();
    for (small, large) in [("count-sketch:32", "count-sketch:2048"), ("srft:24", "srft:256")] {
        let avg_err = |spec: &str| {
            let est = parse_estimator(spec).expect(spec);
            let mut total = 0.0;
            for seed in [101u64, 202, 303] {
                let approx = est.scores(&eng, lambda, &mut Rng::seeded(seed)).unwrap();
                total += rel_err(&approx, &exact);
            }
            total / 3.0
        };
        let (e_small, e_large) = (avg_err(small), avg_err(large));
        assert!(
            e_large < 0.8 * e_small,
            "{large} (err {e_large:.3e}) not clearly better than {small} (err {e_small:.3e})"
        );
    }
}

/// Every randomized estimator is a pure function of its seed (same seed
/// ⇒ bitwise-identical scores), distinct seeds genuinely change the
/// output, and both outputs stay inside a loose accuracy gate.
#[test]
fn estimators_are_seed_sensitive_but_gated() {
    let _g = lock();
    let eng = engine(200, 33);
    let lambda = 1e-2;
    let exact = exact_leverage_scores(&eng, lambda).unwrap();
    for spec in ["bless", "rrls", "count-sketch:256", "srft:64", "rls-nystrom:96"] {
        let run = |seed: u64| {
            let est = parse_estimator(spec).expect(spec);
            est.scores(&eng, lambda, &mut Rng::seeded(seed)).unwrap()
        };
        let (a, b) = check_seed_sensitivity(40, 41, run);
        for (tag, scores) in [("seed 40", &a), ("seed 41", &b)] {
            let stats = RAccStats::from_scores(scores, &exact);
            assert!(
                stats.mean > 0.3 && stats.mean < 3.0,
                "{spec} @ {tag}: mean R-ACC {} outside the loose gate",
                stats.mean
            );
        }
    }
}

/// Householder QR property checks at panel-boundary-straddling shapes,
/// per ISA: QᵀQ = I, A = QR, R upper-triangular with non-negative
/// diagonal, and R = chol(AᵀA)ᵀ on well-conditioned input.
#[test]
fn qr_properties_hold_at_panel_boundaries_per_isa() {
    let _g = lock();
    let shapes = [(95usize, 64usize), (96, 96), (97, 96), (513, 97)];
    for_each_isa(|isa| {
        let tag = isa.name();
        for &(m, k) in &shapes {
            let a = Matrix::from_fn(m, k, |i, j| {
                ((i * k + j) as f64 * 0.61803).sin() + if i == j { 2.0 } else { 0.0 }
            });
            let f = qr(a.clone());
            let (q, r) = (f.thin_q(), f.r());
            for i in 0..k {
                assert!(r.get(i, i) >= 0.0, "({m},{k}) {tag}: negative R diagonal");
                for j in 0..i {
                    assert_eq!(r.get(i, j), 0.0, "({m},{k}) {tag}: R not upper-triangular");
                }
            }
            let qtq = MatMul::tn().run(&q, &q);
            assert!(qtq.max_abs_diff(&Matrix::eye(k)) < 1e-9, "({m},{k}) {tag}: QᵀQ ≠ I");
            let rec = MatMul::nn().run(&q, &r);
            let scale = a.fro_norm().max(1.0);
            assert!(rec.max_abs_diff(&a) / scale < 1e-11, "({m},{k}) {tag}: A ≠ QR");
            // R must agree with the Cholesky route through AᵀA
            let gram = MatMul::tn().lower().run(&a, &a);
            let lt = linalg::cholesky(&gram).expect("Gram SPD").l().transpose();
            assert!(
                r.max_abs_diff(&lt) / lt.fro_norm() < 1e-8,
                "({m},{k}) {tag}: R ≠ chol(AᵀA)ᵀ"
            );
        }
    });
}

/// Regression for the old panic path: non-finite input data makes every
/// jittered factorization attempt fail, which must surface as the typed
/// [`LeverageError::FactorizationFailed`] — not a panic.
#[test]
fn non_finite_data_yields_typed_error_not_panic() {
    let _g = lock();
    let x = Matrix::from_fn(30, 3, |i, j| {
        if i == 7 {
            f64::NAN
        } else {
            ((i * 3 + j) as f64 * 0.37).sin()
        }
    });
    let eng = NativeEngine::new(x, Gaussian::new(2.0));
    let lambda = 1e-2;
    let err = exact_leverage_scores(&eng, lambda).unwrap_err();
    assert!(
        matches!(err, LeverageError::FactorizationFailed { dim: 30, .. }),
        "unexpected error: {err:?}"
    );
    assert!(err.to_string().contains("jitter retries exhausted"), "{err}");
    // the generator path reports the dictionary dimension instead
    let set = WeightedSet::uniform((0..10).collect(), lambda);
    let err = LsGenerator::new(&eng, &set, lambda).unwrap_err();
    assert!(matches!(err, LeverageError::FactorizationFailed { dim: 10, .. }), "{err:?}");
    // and the sketched path flows through the same typed error
    let est = parse_estimator("srft:16").unwrap();
    let err = est.scores(&eng, lambda, &mut Rng::seeded(0)).unwrap_err();
    assert!(matches!(err, LeverageError::FactorizationFailed { .. }), "{err:?}");
}

/// Exactly duplicated points make the kernel matrix rank-deficient; the
/// escalating jitter must rescue the factorization and return finite
/// scores everywhere — for the exact path and the sketched one.
#[test]
fn rank_deficient_kernel_is_rescued_by_jitter() {
    let _g = lock();
    let n = 80;
    // every point appears twice: rank(K) ≤ n/2
    let x = Matrix::from_fn(n, 4, |i, j| (((i / 2) * 4 + j) as f64 * 0.73).sin());
    let eng = NativeEngine::new(x, Gaussian::new(2.0));
    let lambda = 1e-3;
    let exact = exact_leverage_scores(&eng, lambda).unwrap();
    assert_eq!(exact.len(), n);
    assert!(exact.iter().all(|&v| v.is_finite() && v >= 0.0));
    assert!(exact.iter().sum::<f64>() > 0.0, "all-zero exact scores");
    // duplicate pairs share one leverage budget: scores stay bounded
    for est in ["count-sketch:128", "srft:128"] {
        let scores =
            parse_estimator(est).unwrap().scores(&eng, lambda, &mut Rng::seeded(8)).unwrap();
        assert!(
            scores.iter().all(|&v| v.is_finite() && v > 0.0 && v <= 1.0),
            "{est}: non-finite scores on rank-deficient kernel"
        );
    }
}
