//! Integration tests for the PJRT/XLA production path: the full
//! BLESS → FALKON pipeline running on the AOT-compiled Pallas tiles,
//! compared against the native backend. Skipped (with a notice) when
//! `make artifacts` has not been run.

use bless::bless::{bless, BlessConfig};
use bless::data::{auc, susy_like};
use bless::falkon::Falkon;
use bless::kernels::{Gaussian, KernelEngine, NativeEngine};
use bless::leverage::{LsGenerator, WeightedSet};
use bless::rng::Rng;
use bless::runtime::{find_artifact_dir, XlaEngine};

fn engines(n: usize, seed: u64) -> Option<(NativeEngine, XlaEngine, Vec<f64>)> {
    let dir = find_artifact_dir()?;
    let ds = susy_like(n, &mut Rng::seeded(seed));
    let kern = Gaussian::new(4.0);
    let native = NativeEngine::new(ds.x.clone(), kern.clone());
    let xla = XlaEngine::from_artifacts(&dir, ds.x, kern).ok()?;
    Some((native, xla, ds.y))
}

#[test]
fn leverage_scores_agree_across_backends() {
    let Some((native, xla, _)) = engines(500, 21) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let lambda = 1e-3;
    let set = WeightedSet::uniform((0..100).map(|i| i * 5).collect(), lambda);
    let probe: Vec<usize> = (0..50).map(|i| i * 9).collect();
    let sn = LsGenerator::new(&native, &set, lambda).unwrap().scores(&probe);
    let sx = LsGenerator::new(&xla, &set, lambda).unwrap().scores(&probe);
    for (a, b) in sn.iter().zip(&sx) {
        // f32 tiles vs f64 native: agree to ~1e-4 relative
        assert!(
            (a - b).abs() < 2e-4 * a.abs().max(1e-6),
            "score mismatch {a} vs {b}"
        );
    }
}

#[test]
fn bless_on_xla_engine_selects_sane_set() {
    let Some((_, xla, _)) = engines(400, 22) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let path = bless(&xla, 2e-3, &BlessConfig::default(), &mut Rng::seeded(1));
    let set = path.final_set();
    set.validate().unwrap();
    assert!(set.len() >= 8 && set.len() < 400);
}

#[test]
fn full_pipeline_on_xla_matches_native_auc() {
    let Some((native, xla, y)) = engines(800, 23) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let lambda_f = 1e-4;
    // same centers on both backends
    let mut rng = Rng::seeded(2);
    let centers = rng.sample_without_replacement(800, 80);
    let set = WeightedSet::uniform(centers, lambda_f);

    let q = native.points().clone();
    let run = |eng: &dyn KernelEngine| {
        let model = Falkon::new(eng, &set, lambda_f).unwrap().fit(&y, 10, None).unwrap();
        let scores = model.predict(eng, &q);
        auc(&scores, &y)
    };
    let a_native = run(&native);
    let a_xla = run(&xla);
    assert!(a_native > 0.7, "native AUC {a_native}");
    assert!(
        (a_native - a_xla).abs() < 0.01,
        "backend AUC divergence: {a_native} vs {a_xla}"
    );
}
