//! Observability overhead benchmark: what instrumentation costs.
//!
//! Three measurements:
//!
//! 1. **Primitive costs** — one disabled span, one enabled span, one
//!    histogram record, in nanoseconds.
//! 2. **Serve-path overhead** — mean end-to-end predict latency with
//!    histogram recording on vs off, interleaved in alternating phases
//!    on one server so drift hits both sides equally. The ISSUE budget
//!    is ≤2% overhead; the measured number lands in `BENCH_obs.json`.
//! 3. **Scrape sanity** — a raw `GET /metrics` against the same server
//!    must return the per-model latency and batch-size histogram series.
//!
//! ```bash
//! cargo bench --bench obs_overhead
//! cargo bench --bench obs_overhead -- --per 100 --out ../BENCH_obs.json
//! ```

use bless::linalg::Matrix;
use bless::rng::Rng;
use bless::serve::{self, Client, ModelArtifact, ServeConfig};
use bless::util::cli::Args;
use bless::util::json::Json;
use std::collections::BTreeMap;
use std::hint::black_box;
use std::time::Instant;

fn synthetic_artifact(m: usize, d: usize) -> ModelArtifact {
    let mut rng = Rng::seeded(17);
    ModelArtifact {
        sigma: 4.0,
        centers: Matrix::from_fn(m, d, |_, _| rng.gaussian()),
        alpha: (0..m).map(|_| rng.gaussian() * 1e-3).collect(),
        trained_n: m * 4,
        dataset: "obs-bench".to_string(),
    }
}

/// Mean nanoseconds per span enter/drop at the current enable state.
fn span_ns(iters: usize) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        black_box(bless::obs::span("bench.noop"));
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

/// Run `per` fresh (uncacheable) predicts; mean latency in µs.
fn phase(client: &mut Client, d: usize, per: usize, rng: &mut Rng, id: &mut u64) -> f64 {
    let t0 = Instant::now();
    for _ in 0..per {
        let x: Vec<f64> = (0..d).map(|_| rng.gaussian()).collect();
        *id += 1;
        client.predict(*id, &x).expect("predict");
    }
    t0.elapsed().as_secs_f64() * 1e6 / per as f64
}

/// Minimal HTTP GET → (status line, body).
fn http_get(addr: std::net::SocketAddr, path: &str) -> anyhow::Result<(String, String)> {
    use std::io::{Read as _, Write as _};
    let mut s = std::net::TcpStream::connect(addr)?;
    write!(s, "GET {path} HTTP/1.1\r\nHost: bench\r\nConnection: close\r\n\r\n")?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    let (head, body) = buf
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow::anyhow!("malformed HTTP response"))?;
    Ok((head.lines().next().unwrap_or("").to_string(), body.to_string()))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let m = args.get_usize("m", 500);
    let d = args.get_usize("d", 18);
    let rounds = args.get_usize("rounds", 4);
    let per = args.get_usize("per", 200);
    let prim_iters = args.get_usize("prim-iters", 2_000_000);

    println!("== obs_overhead bench: M={m} d={d}, {rounds}×2 phases × {per} requests ==");

    // --- primitive costs
    bless::obs::span::set_enabled(false);
    let span_disabled_ns = span_ns(prim_iters);
    bless::obs::span::set_enabled(true);
    let span_enabled_ns = span_ns(prim_iters / 10);
    bless::obs::span::set_enabled(false);
    bless::obs::span::reset();
    let h = bless::obs::Histogram::new();
    let t0 = Instant::now();
    for i in 0..prim_iters {
        h.record(i as u64 & 0xFFFF);
    }
    let hist_record_ns = t0.elapsed().as_nanos() as f64 / prim_iters as f64;
    println!(
        "primitives     : span off {span_disabled_ns:.1} ns  span on {span_enabled_ns:.1} ns  \
         hist record {hist_record_ns:.1} ns"
    );

    // --- serve-path overhead: alternating recording-on/off phases
    let cfg = ServeConfig::builder()
        .addr("127.0.0.1:0")
        .cache_capacity(0) // every request exercises the full path
        .metrics_addr("127.0.0.1:0")
        .build()?;
    let handle = serve::start(synthetic_artifact(m, d), &cfg)?;
    let mut client = Client::connect(handle.addr())?;
    let mut rng = Rng::seeded(4242);
    let mut id = 0u64;
    phase(&mut client, d, per, &mut rng, &mut id); // warmup

    let (mut on_us, mut off_us) = (Vec::new(), Vec::new());
    for _ in 0..rounds {
        for on in [true, false] {
            bless::obs::metrics::set_serve_recording(on);
            let mean = phase(&mut client, d, per, &mut rng, &mut id);
            let dst = if on { &mut on_us } else { &mut off_us };
            dst.push(mean);
        }
    }
    bless::obs::metrics::set_serve_recording(true);
    let serve_mean_us_on = on_us.iter().sum::<f64>() / on_us.len() as f64;
    let serve_mean_us_off = off_us.iter().sum::<f64>() / off_us.len() as f64;
    let overhead_pct = (serve_mean_us_on - serve_mean_us_off) / serve_mean_us_off * 100.0;
    println!(
        "serve latency  : recording on {serve_mean_us_on:.1} µs  off {serve_mean_us_off:.1} µs  \
         overhead {overhead_pct:+.2}%"
    );

    // --- scrape sanity against the live server
    let maddr = handle.metrics_addr().expect("metrics listener configured");
    let (status, body) = http_get(maddr, "/metrics")?;
    assert!(status.contains("200"), "scrape failed: {status}");
    assert!(body.contains("bless_serve_latency_us_bucket"), "missing latency series:\n{body}");
    assert!(body.contains("bless_serve_batch_size_bucket"), "missing batch series:\n{body}");
    let metrics_lines = body.lines().count();
    let (status, _) = http_get(maddr, "/healthz")?;
    assert!(status.contains("200"), "healthz failed: {status}");
    println!("scrape         : /metrics OK ({metrics_lines} lines), /healthz OK");
    let requests = handle.stats().requests;
    handle.shutdown();

    // --- BENCH_*.json (repo-root schema: flat object of named metrics)
    if let Some(out) = args.get("out") {
        let mut obj = BTreeMap::new();
        let mut put = |k: &str, v: f64| {
            obj.insert(k.to_string(), Json::Num(v));
        };
        put("span_disabled_ns", span_disabled_ns);
        put("span_enabled_ns", span_enabled_ns);
        put("hist_record_ns", hist_record_ns);
        put("serve_mean_us_on", serve_mean_us_on);
        put("serve_mean_us_off", serve_mean_us_off);
        put("overhead_pct", overhead_pct);
        put("metrics_lines", metrics_lines as f64);
        put("requests", requests as f64);
        obj.insert("bench".to_string(), Json::Str("obs".to_string()));
        std::fs::write(out, Json::Obj(obj).to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}
