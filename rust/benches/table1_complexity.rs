//! Bench: Table 1 — per-sampler runtime at growing n and fixed λ, plus
//! the fitted scaling exponents (theory: BLESS/BLESS-R ≈ 0, others ≈ 1).

use bless::coordinator::{table1_complexity, Method, Table1Config};
use bless::util::table::fnum;

fn main() {
    let cfg = Table1Config {
        sizes: vec![500, 1_000, 2_000, 4_000],
        lambda: 1e-3,
        sigma: 4.0,
        seed: 0,
        methods: Method::scalable().to_vec(),
    };
    let (raw, summary) = table1_complexity(&cfg);
    println!("{}", raw.to_console());
    println!("{}", summary.to_console());
    for row in &summary.rows {
        let emp: f64 = row[1].parse().unwrap();
        let theo: f64 = row[2].parse().unwrap();
        println!(
            "  {:<10} empirical {} vs theory {} — {}",
            row[0],
            fnum(emp),
            fnum(theo),
            if (emp - theo).abs() < 0.6 { "SHAPE OK" } else { "shape off (small-n regime)" }
        );
    }
}
