//! Serving-tier benchmark: artifact codec (size + load time, JSON vs
//! binary) and end-to-end server latency under concurrent traffic.
//!
//! Prints a human-readable report and, with `--out <path>`, writes the
//! repo-root `BENCH_*.json` schema (one flat JSON object of named
//! metrics) so CI can track the perf trajectory as a workflow artifact:
//!
//! ```bash
//! cargo bench --bench serve_load                       # full size (M=2000)
//! cargo bench --bench serve_load -- --m 500 \
//!     --clients 4 --per 25 --out ../BENCH_serve.json   # CI smoke size
//! ```

use bless::linalg::Matrix;
use bless::rng::Rng;
use bless::serve::{self, codec, Client, Format, ModelArtifact, ServeConfig};
use bless::util::cli::Args;
use bless::util::json::Json;
use bless::util::quantile;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// A deterministic artifact with trained-weight-like (full-mantissa)
/// values — the honest worst case for both codecs.
fn synthetic_artifact(m: usize, d: usize) -> ModelArtifact {
    let mut rng = Rng::seeded(17);
    ModelArtifact {
        sigma: 4.0,
        centers: Matrix::from_fn(m, d, |_, _| rng.gaussian()),
        alpha: (0..m).map(|_| rng.gaussian() * 1e-3).collect(),
        trained_n: m * 4,
        dataset: "serve-bench".to_string(),
    }
}

/// Best-of-k wall time for `f`, in milliseconds.
fn best_ms(k: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..k {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    best
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let m = args.get_usize("m", 2_000);
    let d = args.get_usize("d", 18);
    let clients = args.get_usize("clients", 8);
    let per_client = args.get_usize("per", 50);
    let load_reps = args.get_usize("load-reps", 3);

    println!("== serve_load bench: M={m} d={d}, {clients} clients × {per_client} requests ==");
    let art = synthetic_artifact(m, d);
    let dir = std::env::temp_dir();
    let json_path = dir.join(format!("bless-serve-bench-{}.json", std::process::id()));
    let bin_path = dir.join(format!("bless-serve-bench-{}.bin", std::process::id()));

    // --- codec: artifact size and load time
    art.save_as(&json_path, Format::Json)?;
    art.save_as(&bin_path, Format::Binary)?;
    let json_bytes = std::fs::metadata(&json_path)?.len();
    let bin_bytes = std::fs::metadata(&bin_path)?.len();
    let json_load_ms = best_ms(load_reps, || {
        ModelArtifact::load(&json_path).expect("json load");
    });
    let bin_load_ms = best_ms(load_reps, || {
        ModelArtifact::load(&bin_path).expect("binary load");
    });
    let size_ratio = json_bytes as f64 / bin_bytes as f64;
    let load_speedup = json_load_ms / bin_load_ms;
    println!(
        "artifact bytes : JSON {json_bytes}  binary {bin_bytes}  ({size_ratio:.2}× smaller)"
    );
    println!(
        "artifact load  : JSON {json_load_ms:.2} ms  binary {bin_load_ms:.2} ms  ({load_speedup:.1}× faster)"
    );

    // sanity: the two encodings serve bit-identical models
    let a = ModelArtifact::load(&json_path)?;
    let b = ModelArtifact::load(&bin_path)?;
    assert_eq!(a.alpha.len(), b.alpha.len());
    for (x, y) in a.alpha.iter().zip(&b.alpha) {
        assert_eq!(x.to_bits(), y.to_bits(), "codec drift");
    }

    // --- end-to-end predict latency under concurrent traffic
    let loaded = ModelArtifact::load(&bin_path)?;
    let cfg = ServeConfig::builder()
        .addr("127.0.0.1:0")
        .workers(2)
        .max_batch(64)
        .linger(Duration::from_millis(2))
        .cache_capacity(0) // every request exercises the GEMM path
        .build()?;
    let handle = serve::start(loaded, &cfg)?;
    let addr = handle.addr();

    let mut joins = Vec::new();
    for c in 0..clients {
        let seed = 1000 + c as u64;
        joins.push(std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
            let mut rng = Rng::seeded(seed);
            let mut client = Client::connect(addr)?;
            let mut lat_us = Vec::with_capacity(per_client);
            for k in 0..per_client {
                let x: Vec<f64> = (0..d).map(|_| rng.gaussian()).collect();
                let t0 = Instant::now();
                client.predict(k as u64, &x)?;
                lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
            }
            Ok(lat_us)
        }));
    }
    let mut lat_us: Vec<f64> = Vec::new();
    for j in joins {
        lat_us.extend(j.join().expect("client thread panicked")?);
    }
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (p50, p99) = (quantile(&lat_us, 0.5), quantile(&lat_us, 0.99));
    let stats = handle.stats();
    let mean_batch = stats.mean_batch();
    println!(
        "predict latency: p50 {p50:.0} µs  p99 {p99:.0} µs  over {} requests (mean batch {mean_batch:.2})",
        stats.requests
    );
    // server-side view: derived from the per-model latency histogram,
    // excludes client/TCP round-trip time
    println!(
        "server-side    : p50 {:.0} µs  p95 {:.0} µs  p99 {:.0} µs (from the latency histogram)",
        stats.latency_p50_us, stats.latency_p95_us, stats.latency_p99_us
    );
    handle.shutdown();
    std::fs::remove_file(&json_path).ok();
    std::fs::remove_file(&bin_path).ok();

    // --- BENCH_*.json (repo-root schema: flat object of named metrics)
    if let Some(out) = args.get("out") {
        let mut obj = BTreeMap::new();
        let mut put = |k: &str, v: f64| {
            obj.insert(k.to_string(), Json::Num(v));
        };
        put("m", m as f64);
        put("d", d as f64);
        put("json_bytes", json_bytes as f64);
        put("bin_bytes", bin_bytes as f64);
        put("size_ratio", size_ratio);
        put("json_load_ms", json_load_ms);
        put("bin_load_ms", bin_load_ms);
        put("load_speedup", load_speedup);
        put("p50_predict_us", p50);
        put("p99_predict_us", p99);
        put("server_p50_us", stats.latency_p50_us);
        put("server_p95_us", stats.latency_p95_us);
        put("server_p99_us", stats.latency_p99_us);
        put("mean_batch", mean_batch);
        put("requests", stats.requests as f64);
        put("binary_version", codec::BINARY_VERSION as f64);
        obj.insert("bench".to_string(), Json::Str("serve".to_string()));
        std::fs::write(out, Json::Obj(obj).to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}
