//! Ablation: the BLESS oversampling constant q₂ (Thm. 1 asks for a large
//! log-factor constant; the experiments use small ones). Sweeps q₂ and
//! reports |J|, runtime and mean R-ACC — the accuracy/cost trade-off the
//! DESIGN.md §3 defaults were tuned on.

use bless::bless::{bless, BlessConfig};
use bless::data::susy_like;
use bless::kernels::{Gaussian, NativeEngine};
use bless::leverage::{exact_leverage_scores, LsGenerator, RAccStats};
use bless::rng::Rng;
use bless::util::table::{fnum, Table};
use bless::util::timed;

fn main() {
    let n = 1_500;
    let lambda = 1e-4;
    let ds = susy_like(n, &mut Rng::seeded(7));
    let eng = NativeEngine::new(ds.x, Gaussian::new(4.0));
    let exact = exact_leverage_scores(&eng, lambda).unwrap();
    let all: Vec<usize> = (0..n).collect();

    let mut table = Table::new(
        &format!("Ablation: BLESS q2 sweep (n={n}, λ={lambda:.0e})"),
        &["q2", "|J|", "time_s", "R-ACC", "q05", "q95"],
    );
    for &q2 in &[1.0, 2.0, 4.0, 8.0, 16.0] {
        let cfg = BlessConfig { q2, ..Default::default() };
        let mut rng = Rng::seeded(13);
        let (path, secs) = timed(|| bless(&eng, lambda, &cfg, &mut rng));
        let gen = LsGenerator::new(&eng, path.final_set(), lambda).unwrap();
        let stats = RAccStats::from_scores(&gen.scores(&all), &exact);
        table.row(&[
            fnum(q2),
            path.final_set().len().to_string(),
            fnum(secs),
            fnum(stats.mean),
            fnum(stats.q05),
            fnum(stats.q95),
        ]);
    }
    println!("{}", table.to_console());
    println!("expected shape: q05→1 and q95→1 as q2 grows, |J| ∝ q2.");
}
