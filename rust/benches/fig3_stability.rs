//! Bench: Figure 3 — λ_falkon stability sweep (c-err after 5 iterations),
//! reporting the width of each method's 95%-optimal region.

use bless::coordinator::{build_engine, fig3_stability, EngineKind, Fig3Config};
use bless::data::susy_like;
use bless::kernels::Gaussian;
use bless::rng::Rng;

fn main() {
    let mut rng = Rng::seeded(0);
    let ds = susy_like(2_500, &mut rng);
    let (train, test) = ds.split(0.25, &mut rng);
    let eng = build_engine(EngineKind::Native, train.x.clone(), Gaussian::new(4.0)).unwrap();
    let cfg = Fig3Config::default();
    let res = fig3_stability(eng.as_dyn(), &train.y, &test, &cfg).unwrap();
    println!("{}", res.table.to_console());
    println!(
        "region width: BLESS {:.2} decades vs UNI {:.2} decades — {}",
        res.bless_region_decades,
        res.uni_region_decades,
        if res.bless_region_decades >= res.uni_region_decades {
            "SHAPE OK (BLESS at least as wide)"
        } else {
            "shape off"
        }
    );
}
