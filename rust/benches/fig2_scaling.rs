//! Bench: Figure 2 — runtime vs n at λ=1e-3 for all scalable samplers.
//! The paper's claim under test: BLESS/BLESS-R flat, others near-linear.

use bless::coordinator::{fig2_scaling, scaling_exponent, Fig2Config};

fn main() {
    let cfg = Fig2Config {
        sizes: vec![1_000, 2_000, 4_000, 8_000],
        lambda: 1e-3,
        ..Default::default()
    };
    let t = fig2_scaling(&cfg);
    println!("{}", t.to_console());
    println!("log-log slope of time vs n:");
    for &m in &cfg.methods {
        println!("  {:<10} {:+.2}", m.name(), scaling_exponent(&t, m));
    }
}
