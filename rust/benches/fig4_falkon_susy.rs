//! Bench: Figure 4 — FALKON-BLESS vs FALKON-UNI AUC/iteration on
//! SUSY-like data (the end-to-end system benchmark).

use bless::coordinator::{build_engine, fig45_falkon, EngineKind, Fig45Config};
use bless::data::susy_like;
use bless::kernels::Gaussian;
use bless::rng::Rng;
use bless::util::cli::Args;
use bless::util::pool;

fn main() {
    let args = Args::parse();
    pool::set_threads(args.get_usize("threads", 0));
    println!("threads: {}", pool::threads());
    let mut rng = Rng::seeded(0);
    let ds = susy_like(args.get_usize("n", 6_000), &mut rng);
    let (train, test) = ds.split(0.25, &mut rng);
    let eng = build_engine(EngineKind::Native, train.x.clone(), Gaussian::new(4.0)).unwrap();
    let cfg = Fig45Config { iterations: 15, ..Fig45Config::susy() };
    let (b, u, table) = fig45_falkon(eng.as_dyn(), &train.y, &test, &cfg).unwrap();
    println!("{}", table.to_console());
    println!(
        "BLESS M={} final {:.4} | UNI M={} final {:.4}",
        b.centers,
        b.final_auc(),
        u.centers,
        u.final_auc()
    );
    match b.iters_to_reach(u.final_auc()) {
        Some(it) => println!("BLESS matches UNI-final AUC at iter {it}/15 — SHAPE OK"),
        None => println!("BLESS did not reach UNI-final AUC — shape off"),
    }
}
