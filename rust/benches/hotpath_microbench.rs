//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! GEMM, Cholesky, kernel-block evaluation (native + XLA tile), the
//! LsGenerator batch scoring, and the FALKON fused CG matvec.

use bless::data::susy_like;
use bless::kernels::{Gaussian, KernelEngine, NativeEngine};
use bless::leverage::{LsGenerator, WeightedSet};
use bless::linalg::{cholesky, gemm, Matrix};
use bless::rng::Rng;
use bless::util::bench::Bencher;

fn main() {
    let mut b = Bencher::with_budget(3.0);

    // --- GEMM (the engine's inner loop shape: tall × small-d and square)
    let a512 = Matrix::from_fn(512, 512, |i, j| ((i * 31 + j * 17) % 19) as f64 * 0.05);
    let b512 = Matrix::from_fn(512, 512, |i, j| ((i * 13 + j * 7) % 23) as f64 * 0.04);
    b.bench("gemm 512x512x512", || gemm(&a512, &b512));
    let tall = Matrix::from_fn(4_096, 18, |i, j| ((i + j) % 11) as f64 * 0.1);
    let wide = tall.transpose();
    b.bench("gemm 4096x18 · 18x4096 (kernel cross-term)", || gemm(&tall, &wide));

    // --- Cholesky (LsGenerator / preconditioner factorizations)
    let mut spd = gemm(&a512, &a512.transpose());
    spd.add_scaled_identity(600.0);
    b.bench("cholesky 512", || cholesky(&spd).unwrap());

    // --- kernel block evaluation
    let ds = susy_like(4_096, &mut Rng::seeded(3));
    let eng = NativeEngine::new(ds.x.clone(), Gaussian::new(4.0));
    let rows: Vec<usize> = (0..1024).collect();
    let cols: Vec<usize> = (0..512).map(|i| i * 8).collect();
    b.bench("native kernel block 1024x512", || eng.block(&rows, &cols));

    // --- XLA tile path (if artifacts are built)
    if let Some(dir) = bless::runtime::find_artifact_dir() {
        let xla =
            bless::runtime::XlaEngine::from_artifacts(&dir, ds.x.clone(), Gaussian::new(4.0))
                .unwrap();
        b.bench("xla kernel block 1024x512 (PJRT tiles)", || xla.block(&rows, &cols));
        let t = xla.tile();
        let trows: Vec<usize> = (0..t).collect();
        b.bench("xla single tile TxT", || xla.block(&trows, &trows));
    } else {
        println!("(artifacts not built; skipping XLA benches)");
    }

    // --- leverage-score batch evaluation (BLESS inner loop)
    let set = WeightedSet::uniform((0..256).map(|i| i * 16).collect(), 1e-3);
    let gen = LsGenerator::new(&eng, &set, 1e-3).unwrap();
    let batch: Vec<usize> = (0..1_000).collect();
    b.bench("LsGenerator::scores batch=1000 |J|=256", || gen.scores(&batch));

    // --- FALKON fused CG matvec
    let centers: Vec<usize> = (0..256).map(|i| i * 16).collect();
    let v: Vec<f64> = (0..256).map(|i| ((i as f64) * 0.1).sin()).collect();
    b.bench("knm_t_knm_matvec n=4096 M=256", || eng.knm_t_knm_matvec(&centers, &v));

    b.summary("hot-path microbenchmarks");
}
