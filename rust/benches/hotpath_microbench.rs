//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! GEMM (including the transpose-free `MatMul::nt` kernel cross-term),
//! Cholesky, kernel-block evaluation (native + XLA tile), the
//! LsGenerator batch scoring, and the FALKON fused CG matvec — plus a
//! serial-vs-parallel scaling section for the shared threadpool, a
//! CG-iteration-throughput section comparing streamed vs panel-cached
//! FALKON training, and a scalar-vs-AVX2 section for the runtime-
//! dispatched SIMD micro-kernel tier.
//!
//! ```bash
//! cargo bench --bench hotpath_microbench                   # all cores
//! cargo bench --bench hotpath_microbench -- --threads 4
//! cargo bench --bench hotpath_microbench -- \
//!     --out ../BENCH_parallel.json \
//!     --falkon-out ../BENCH_falkon.json \
//!     --chol-out ../BENCH_chol.json \
//!     --simd-out ../BENCH_simd.json  # emit the repo-root schemas
//! ```
//!
//! With `--out`, writes `BENCH_parallel.json` (flat object of named
//! metrics: 1-thread vs N-thread GEMM and kernel-block GFLOP/s and the
//! speedups). With `--falkon-out`, writes `BENCH_falkon.json` (FALKON
//! train wall-clock + kernel-eval counts streamed vs cached, and
//! `MatMul::nt` vs gemm-plus-transpose GFLOP/s) so CI can track the
//! panel cache's trajectory. `--falkon-n/--falkon-m/--falkon-iters`
//! resize the training shape (default n=8000, M=800, t=10 — the
//! SUSY-like shape of the ISSUE acceptance bar). With `--chol-out`,
//! writes `BENCH_chol.json` (serial-vs-N-thread Cholesky GF/s at
//! M=512/1024/2048, the `syrk_tn_of_lower` vs `MatMul::tn` G-build,
//! preconditioner build wall-clock, and the multi-RHS `LᵀX=B` TRSM).
//! With `--simd-out`, writes `BENCH_simd.json` (GEMM / SYRK / Cholesky /
//! kernel-block GF/s under `linalg::set_isa(Scalar)` vs `Avx2` and the
//! per-shape speedups; AVX2 rows are omitted on hosts without AVX2+FMA).

use bless::data::susy_like;
use bless::falkon::{Falkon, Preconditioner};
use bless::kernels::{Gaussian, KernelEngine, NativeEngine};
use bless::leverage::{LsGenerator, WeightedSet};
use bless::linalg::{
    self, cholesky, gemm, solve_upper_from_lower_matrix, syrk, syrk_tn_of_lower, MatMul, Matrix,
};
use bless::rng::Rng;
use bless::util::bench::{black_box, Bencher};
use bless::util::cli::Args;
use bless::util::json::Json;
use bless::util::pool;
use std::collections::BTreeMap;

fn main() {
    let args = Args::parse();
    pool::set_threads(args.get_usize("threads", 0));
    let nthreads = pool::threads();
    let mut b = Bencher::with_budget(3.0);

    // --- GEMM (the engine's inner loop shape: tall × small-d and square)
    let a512 = Matrix::from_fn(512, 512, |i, j| ((i * 31 + j * 17) % 19) as f64 * 0.05);
    let b512 = Matrix::from_fn(512, 512, |i, j| ((i * 13 + j * 7) % 23) as f64 * 0.04);
    b.bench("gemm 512x512x512", || gemm(&a512, &b512));
    let tall = Matrix::from_fn(4_096, 18, |i, j| ((i + j) % 11) as f64 * 0.1);
    let wide = tall.transpose();
    b.bench("gemm 4096x18 · 18x4096 (kernel cross-term)", || gemm(&tall, &wide));

    // --- transpose-free kernel cross-term: MatMul::nt vs gemm + transpose
    let cmat = Matrix::from_fn(512, 18, |i, j| ((i * 5 + j * 3) % 13) as f64 * 0.07);
    let nt_t = b
        .bench("gemm 4096x18 · (512x18)ᵀ (explicit transpose)", || {
            gemm(&tall, &cmat.transpose())
        })
        .clone();
    let nt_d = b
        .bench("MatMul::nt 4096x18 · 512x18 (transpose-free)", || MatMul::nt().run(&tall, &cmat))
        .clone();
    assert!(
        gemm(&tall, &cmat.transpose()).max_abs_diff(&MatMul::nt().run(&tall, &cmat)) < 1e-9,
        "MatMul::nt disagrees with gemm + transpose"
    );

    // (Cholesky moved to the factorization-tier section below: serial
    //  and parallel rows at 512/1024/2048 on the shared SPD probe.)

    // --- kernel block evaluation
    let ds = susy_like(4_096, &mut Rng::seeded(3));
    let eng = NativeEngine::new(ds.x.clone(), Gaussian::new(4.0));
    let rows: Vec<usize> = (0..1024).collect();
    let cols: Vec<usize> = (0..512).map(|i| i * 8).collect();
    b.bench("native kernel block 1024x512", || eng.block(&rows, &cols));

    // --- XLA tile path (if artifacts are built)
    if let Some(dir) = bless::runtime::find_artifact_dir() {
        let xla =
            bless::runtime::XlaEngine::from_artifacts(&dir, ds.x.clone(), Gaussian::new(4.0))
                .unwrap();
        b.bench("xla kernel block 1024x512 (PJRT tiles)", || xla.block(&rows, &cols));
        let t = xla.tile();
        let trows: Vec<usize> = (0..t).collect();
        b.bench("xla single tile TxT", || xla.block(&trows, &trows));
    } else {
        println!("(artifacts not built; skipping XLA benches)");
    }

    // --- leverage-score batch evaluation (BLESS inner loop)
    let set = WeightedSet::uniform((0..256).map(|i| i * 16).collect(), 1e-3);
    let gen = LsGenerator::new(&eng, &set, 1e-3).unwrap();
    let batch: Vec<usize> = (0..1_000).collect();
    b.bench("LsGenerator::scores batch=1000 |J|=256", || gen.scores(&batch));

    // --- FALKON fused CG matvec
    let centers: Vec<usize> = (0..256).map(|i| i * 16).collect();
    let v: Vec<f64> = (0..256).map(|i| ((i as f64) * 0.1).sin()).collect();
    b.bench("knm_t_knm_matvec n=4096 M=256", || eng.knm_t_knm_matvec(&centers, &v));

    // --- serial vs parallel scaling (the shared threadpool)
    println!("\n-- threadpool scaling: 1 vs {nthreads} threads --");
    pool::set_threads(1);
    let gemm_s = b.bench("gemm 512x512x512 (1 thread)", || gemm(&a512, &b512)).clone();
    let kblk_s =
        b.bench("native kernel block 1024x512 (1 thread)", || eng.block(&rows, &cols)).clone();
    let reference = gemm(&a512, &b512);
    let ref_block = eng.block(&rows, &cols);
    pool::set_threads(nthreads);
    let gemm_p = b
        .bench(&format!("gemm 512x512x512 ({nthreads} threads)"), || gemm(&a512, &b512))
        .clone();
    let kblk_p = b
        .bench(&format!("native kernel block 1024x512 ({nthreads} threads)"), || {
            eng.block(&rows, &cols)
        })
        .clone();
    // determinism spot-check: the parallel results must be bit-identical
    let par = gemm(&a512, &b512);
    for (x, y) in reference.as_slice().iter().zip(par.as_slice()) {
        assert_eq!(x.to_bits(), y.to_bits(), "parallel gemm diverged from serial");
    }
    let par_block = eng.block(&rows, &cols);
    for (x, y) in ref_block.as_slice().iter().zip(par_block.as_slice()) {
        assert_eq!(x.to_bits(), y.to_bits(), "parallel kernel block diverged from serial");
    }

    // GFLOP/s: gemm = 2·m·n·k; kernel block ≈ cross-term gemm (2·r·c·d)
    // plus the norm/exp pass (~3 flops/cell; the exp itself is counted
    // as one).
    let gemm_flops = 2.0 * 512.0 * 512.0 * 512.0;
    let kblk_flops = (1024 * 512) as f64 * (2.0 * 18.0 + 3.0);
    let gemm_gfs_serial = gemm_flops / gemm_s.median_s / 1e9;
    let gemm_gfs_par = gemm_flops / gemm_p.median_s / 1e9;
    let kblk_gfs_serial = kblk_flops / kblk_s.median_s / 1e9;
    let kblk_gfs_par = kblk_flops / kblk_p.median_s / 1e9;
    println!(
        "gemm 512³      : {gemm_gfs_serial:.2} → {gemm_gfs_par:.2} GFLOP/s  \
         ({:.2}× on {nthreads} threads)",
        gemm_s.median_s / gemm_p.median_s
    );
    println!(
        "kernel block   : {kblk_gfs_serial:.2} → {kblk_gfs_par:.2} GFLOP/s  \
         ({:.2}× on {nthreads} threads)",
        kblk_s.median_s / kblk_p.median_s
    );

    // --- factorization tier: blocked Cholesky / syrk / TRSM, serial vs
    //     parallel (the chol-2048 row is the ISSUE-5 acceptance bar).
    println!("\n-- factorization tier: serial vs {nthreads} threads --");
    let spd_of = Matrix::spd_probe;
    // (n, serial GF/s, parallel GF/s, speedup)
    let mut chol_rows: Vec<(usize, f64, f64, f64)> = Vec::new();
    for &cn in &[512usize, 1024, 2048] {
        let a = spd_of(cn);
        pool::set_threads(1);
        let s = b.bench(&format!("cholesky {cn} (1 thread)"), || cholesky(&a).unwrap()).clone();
        let f_serial = cholesky(&a).unwrap();
        pool::set_threads(nthreads);
        let p = b
            .bench(&format!("cholesky {cn} ({nthreads} threads)"), || cholesky(&a).unwrap())
            .clone();
        let f_par = cholesky(&a).unwrap();
        for (x, y) in f_serial.l().as_slice().iter().zip(f_par.l().as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits(), "parallel cholesky diverged at n={cn}");
        }
        // standard Cholesky flop count: n³/3
        let flops = (cn as f64).powi(3) / 3.0;
        let gfs = flops / s.median_s / 1e9;
        let gfp = flops / p.median_s / 1e9;
        let speedup = s.median_s / p.median_s;
        println!(
            "cholesky {cn:<5}: {gfs:.2} → {gfp:.2} GF/s  ({speedup:.2}× on {nthreads} threads)"
        );
        chol_rows.push((cn, gfs, gfp, speedup));
    }

    // G-build for the FALKON preconditioner: triangular rank-k update vs
    // the dense MatMul::tn(L, L) it replaced, plus whole-precond
    // wall-clock.
    let gm = 1024usize;
    let spd_g = spd_of(gm);
    let lfac = cholesky(&spd_g).unwrap();
    let g_gemm = b.bench("G build: MatMul::tn(L, L) 1024 (dense)", || {
        MatMul::tn().run(lfac.l(), lfac.l())
    });
    let g_gemm_ms = g_gemm.median_s * 1e3;
    let g_syrk =
        b.bench("G build: syrk_tn_of_lower(L) 1024", || syrk_tn_of_lower(lfac.l())).clone();
    let g_syrk_ms = g_syrk.median_s * 1e3;
    assert!(
        syrk_tn_of_lower(lfac.l()).max_abs_diff(&MatMul::tn().run(lfac.l(), lfac.l())) < 1e-8,
        "syrk_tn_of_lower disagrees with MatMul::tn"
    );
    let weights = vec![1.0; gm];
    pool::set_threads(1);
    let pre_s = b
        .bench("Preconditioner::new M=1024 (1 thread)", || {
            Preconditioner::new(&spd_g, &weights, 8 * gm, 1e-3).unwrap()
        })
        .clone();
    pool::set_threads(nthreads);
    let pre_p = b
        .bench(&format!("Preconditioner::new M=1024 ({nthreads} threads)"), || {
            Preconditioner::new(&spd_g, &weights, 8 * gm, 1e-3).unwrap()
        })
        .clone();
    println!(
        "precond build  : {:.1} ms → {:.1} ms  ({:.2}× on {nthreads} threads; \
         G via syrk {g_syrk_ms:.1} ms vs gemm_tn {g_gemm_ms:.1} ms)",
        pre_s.median_s * 1e3,
        pre_p.median_s * 1e3,
        pre_s.median_s / pre_p.median_s
    );

    // multi-RHS back substitution Lᵀ X = B off the stored lower factor
    let rhs = Matrix::from_fn(gm, 512, |i, j| ((i * 512 + j) as f64 * 0.11).sin());
    pool::set_threads(1);
    let trsm_s = b
        .bench("solve LᵀX=B 1024×512 (1 thread)", || {
            solve_upper_from_lower_matrix(lfac.l(), &rhs)
        })
        .clone();
    pool::set_threads(nthreads);
    let trsm_p = b
        .bench(&format!("solve LᵀX=B 1024×512 ({nthreads} threads)"), || {
            solve_upper_from_lower_matrix(lfac.l(), &rhs)
        })
        .clone();
    let trsm_flops = (gm * gm) as f64 * 512.0; // n²/2 madds × 2 flops, per RHS column
    let trsm_gfs = trsm_flops / trsm_s.median_s / 1e9;
    let trsm_gfp = trsm_flops / trsm_p.median_s / 1e9;
    println!(
        "trsm LᵀX=B     : {trsm_gfs:.2} → {trsm_gfp:.2} GF/s  ({:.2}× on {nthreads} threads)",
        trsm_s.median_s / trsm_p.median_s
    );

    // --- FALKON CG-iteration throughput: streamed vs cached K_nM panel.
    // Whole-train wall-clock (solver construction + t CG iterations), so
    // the cached side pays for its one materialization sweep up front.
    let fk_n = args.get_usize("falkon-n", 8_000);
    let fk_m = args.get_usize("falkon-m", 800).min(fk_n);
    let fk_iters = args.get_usize("falkon-iters", 10);
    println!(
        "\n-- FALKON CG throughput (n={fk_n}, M={fk_m}, t={fk_iters}): \
         streamed vs panel-cached K_nM --"
    );
    let fk_ds = susy_like(fk_n, &mut Rng::seeded(5));
    let fk_eng = NativeEngine::new(fk_ds.x.clone(), Gaussian::new(4.0));
    let fk_centers = Rng::seeded(6).sample_without_replacement(fk_n, fk_m);
    let fk_set = WeightedSet::uniform(fk_centers, 1e-5);
    let train_at = |budget: usize| {
        let t0 = std::time::Instant::now();
        let solver = Falkon::with_budget(&fk_eng, &fk_set, 1e-5, budget).unwrap();
        let model = solver.fit(&fk_ds.y, fk_iters, None).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        black_box(model.alpha.len());
        (secs, solver.panel().stats().entries_evaluated)
    };
    let (fk_streamed_s, fk_streamed_evals) = train_at(0);
    let (fk_cached_s, fk_cached_evals) = train_at(usize::MAX);
    let fk_speedup = fk_streamed_s / fk_cached_s;
    println!(
        "streamed (budget 0)  : {fk_streamed_s:8.2}s  ({fk_streamed_evals} kernel evals)"
    );
    println!(
        "cached (unbounded)   : {fk_cached_s:8.2}s  ({fk_cached_evals} kernel evals)  \
         {fk_speedup:.2}× faster"
    );

    // --- SIMD micro-kernel tier: scalar vs AVX2 backend at a fixed
    //     thread count. Thread-count determinism is asserted above;
    //     cross-ISA accuracy is gated in tests/isa_dispatch.rs — here we
    //     only measure what the explicit AVX2+FMA tiles buy per shape.
    println!("\n-- SIMD dispatch: scalar vs avx2 micro-kernels ({nthreads} threads) --");
    let have_avx2 = linalg::set_isa(linalg::Isa::Avx2).is_ok();
    if !have_avx2 {
        println!("(no AVX2+FMA on this host; scalar rows only)");
    }
    let syrk_a = Matrix::from_fn(1024, 256, |i, j| ((i * 7 + j * 3) % 17) as f64 * 0.06);
    type Shape<'a> = (&'a str, f64, Box<dyn Fn() + 'a>);
    let shapes: Vec<Shape<'_>> = vec![
        (
            "gemm_nn_512",
            2.0 * 512.0f64.powi(3),
            Box::new(|| {
                black_box(gemm(&a512, &b512));
            }),
        ),
        (
            "gemm_nt_4096x512x18",
            2.0 * 4_096.0 * 512.0 * 18.0,
            Box::new(|| {
                black_box(MatMul::nt().run(&tall, &cmat));
            }),
        ),
        (
            "syrk_1024x256",
            (1024 * 1024) as f64 * 256.0,
            Box::new(|| {
                black_box(syrk(&syrk_a));
            }),
        ),
        (
            "chol_1024",
            1024.0f64.powi(3) / 3.0,
            Box::new(|| {
                black_box(cholesky(&spd_g).unwrap());
            }),
        ),
        (
            "kernel_block_1024x512",
            kblk_flops,
            Box::new(|| {
                black_box(eng.block(&rows, &cols));
            }),
        ),
    ];
    // (name, scalar GF/s, avx2 GF/s, speedup) — avx2 fields 0 when absent
    let mut simd_rows: Vec<(&str, f64, f64, f64)> = Vec::new();
    for (name, flops, f) in &shapes {
        let (name, flops) = (*name, *flops);
        linalg::set_isa(linalg::Isa::Scalar).unwrap();
        let s = b.bench(&format!("{name} (scalar)"), f).clone();
        let gf_s = flops / s.median_s / 1e9;
        if have_avx2 {
            linalg::set_isa(linalg::Isa::Avx2).unwrap();
            let v = b.bench(&format!("{name} (avx2)"), f).clone();
            let gf_v = flops / v.median_s / 1e9;
            let speedup = s.median_s / v.median_s;
            println!("{name:<22}: {gf_s:.2} → {gf_v:.2} GF/s  ({speedup:.2}× with avx2)");
            simd_rows.push((name, gf_s, gf_v, speedup));
        } else {
            println!("{name:<22}: {gf_s:.2} GF/s (scalar only)");
            simd_rows.push((name, gf_s, 0.0, 0.0));
        }
    }
    linalg::set_isa_from_str("auto").expect("auto re-detect");

    b.summary("hot-path microbenchmarks");

    // GFLOP/s of the transpose-free cross-term vs gemm + transpose
    let nt_flops = 2.0 * 4_096.0 * 512.0 * 18.0;
    let nt_gfs_transpose = nt_flops / nt_t.median_s / 1e9;
    let nt_gfs_direct = nt_flops / nt_d.median_s / 1e9;
    println!(
        "gemm_nt cross-term: {nt_gfs_transpose:.2} (via transpose) → {nt_gfs_direct:.2} \
         GFLOP/s ({:.2}×, zero transpose allocations)",
        nt_t.median_s / nt_d.median_s
    );

    // --- BENCH_chol.json (repo-root schema: flat object of metrics)
    if let Some(out) = args.get("chol-out") {
        let mut obj = BTreeMap::new();
        let mut put = |k: &str, v: f64| {
            obj.insert(k.to_string(), Json::Num(v));
        };
        put("threads", nthreads as f64);
        for &(cn, gfs, gfp, speedup) in &chol_rows {
            put(&format!("chol{cn}_gflops_serial"), gfs);
            put(&format!("chol{cn}_gflops_parallel"), gfp);
            put(&format!("chol{cn}_speedup"), speedup);
        }
        put("g_syrk_ms", g_syrk_ms);
        put("g_gemm_tn_ms", g_gemm_ms);
        put("g_syrk_speedup", g_gemm_ms / g_syrk_ms);
        put("precond_build_serial_ms", pre_s.median_s * 1e3);
        put("precond_build_parallel_ms", pre_p.median_s * 1e3);
        put("precond_build_speedup", pre_s.median_s / pre_p.median_s);
        put("trsm_gflops_serial", trsm_gfs);
        put("trsm_gflops_parallel", trsm_gfp);
        put("trsm_speedup", trsm_s.median_s / trsm_p.median_s);
        obj.insert("bench".to_string(), Json::Str("chol".to_string()));
        std::fs::write(out, Json::Obj(obj).to_string()).expect("writing BENCH json");
        println!("wrote {out}");
    }

    // --- BENCH_falkon.json (repo-root schema: flat object of metrics)
    if let Some(out) = args.get("falkon-out") {
        let mut obj = BTreeMap::new();
        let mut put = |k: &str, v: f64| {
            obj.insert(k.to_string(), Json::Num(v));
        };
        put("threads", nthreads as f64);
        put("falkon_n", fk_n as f64);
        put("falkon_m", fk_m as f64);
        put("falkon_iters", fk_iters as f64);
        put("falkon_train_streamed_s", fk_streamed_s);
        put("falkon_train_cached_s", fk_cached_s);
        put("falkon_cached_speedup", fk_speedup);
        put("kernel_evals_streamed", fk_streamed_evals as f64);
        put("kernel_evals_cached", fk_cached_evals as f64);
        put("gemm_nt_gflops", nt_gfs_direct);
        put("gemm_transpose_gflops", nt_gfs_transpose);
        put("gemm_nt_speedup", nt_t.median_s / nt_d.median_s);
        obj.insert("bench".to_string(), Json::Str("falkon".to_string()));
        std::fs::write(out, Json::Obj(obj).to_string()).expect("writing BENCH json");
        println!("wrote {out}");
    }

    // --- BENCH_*.json (repo-root schema: flat object of named metrics)
    if let Some(out) = args.get("out") {
        let mut obj = BTreeMap::new();
        let mut put = |k: &str, v: f64| {
            obj.insert(k.to_string(), Json::Num(v));
        };
        put("threads", nthreads as f64);
        put("gemm_gflops_serial", gemm_gfs_serial);
        put("gemm_gflops_parallel", gemm_gfs_par);
        put("gemm_speedup", gemm_s.median_s / gemm_p.median_s);
        put("kblock_gflops_serial", kblk_gfs_serial);
        put("kblock_gflops_parallel", kblk_gfs_par);
        put("kblock_speedup", kblk_s.median_s / kblk_p.median_s);
        obj.insert("bench".to_string(), Json::Str("parallel".to_string()));
        std::fs::write(out, Json::Obj(obj).to_string()).expect("writing BENCH json");
        println!("wrote {out}");
    }

    // --- BENCH_simd.json (repo-root schema: flat object of metrics)
    if let Some(out) = args.get("simd-out") {
        let mut obj = BTreeMap::new();
        let mut put = |k: &str, v: f64| {
            obj.insert(k.to_string(), Json::Num(v));
        };
        put("threads", nthreads as f64);
        put("avx2_available", if have_avx2 { 1.0 } else { 0.0 });
        for &(name, gf_s, gf_v, speedup) in &simd_rows {
            put(&format!("{name}_gflops_scalar"), gf_s);
            if have_avx2 {
                put(&format!("{name}_gflops_avx2"), gf_v);
                put(&format!("{name}_simd_speedup"), speedup);
            }
        }
        obj.insert("bench".to_string(), Json::Str("simd".to_string()));
        std::fs::write(out, Json::Obj(obj).to_string()).expect("writing BENCH json");
        println!("wrote {out}");
    }
}
