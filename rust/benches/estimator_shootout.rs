//! Estimator-family shoot-out bench: every [`bless::leverage`] estimator
//! (exact, BLESS, RRLS, count-sketch, SRFT, recursive-RLS Nyström) on
//! the same SUSY-like kernel — accuracy (R-ACC vs the exact scores),
//! wall-clock, metered kernel-entry evaluations and peak dense
//! workspace — plus a small size sweep for the empirical n-exponents.
//!
//! ```bash
//! cargo bench --bench estimator_shootout
//! cargo bench --bench estimator_shootout -- \
//!     --n 500 --reps 2 --seed 7 --sizes 250,500 \
//!     --out ../BENCH_estimators.json
//! ```
//!
//! With `--out`, writes the repo-root `BENCH_estimators.json` schema: a
//! flat object with one `<estimator>_{racc_mean,racc_q05,racc_q95,
//! time_s,kernel_evals,peak_mb}` group per family member (names
//! sanitized to `[a-z0-9_]`) plus `<estimator>_n_exponent` slopes from
//! the sweep, so CI can track accuracy-vs-cost trajectories per PR.

use bless::coordinator::{
    fig1_estimator_shootout, fig2_estimator_scaling, scaling_exponent_for, Fig2Config,
    ShootoutConfig,
};
use bless::data::susy_like;
use bless::kernels::{Gaussian, NativeEngine};
use bless::leverage::parse_estimator;
use bless::rng::Rng;
use bless::util::cli::Args;
use bless::util::json::Json;
use bless::util::pool;
use std::collections::BTreeMap;

/// Flatten an estimator display name into a JSON metric prefix:
/// `count-sketch(s=256)` → `count_sketch_s_256`.
fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for c in name.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c.to_ascii_lowercase());
        } else if !out.ends_with('_') {
            out.push('_');
        }
    }
    out.trim_matches('_').to_string()
}

fn parse_specs(args: &Args, default: &[String]) -> Vec<String> {
    match args.get("estimators") {
        None => default.to_vec(),
        Some(list) => match list.trim() {
            "default" | "all" => default.to_vec(),
            other => {
                other.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
            }
        },
    }
}

fn main() {
    let args = Args::parse();
    pool::set_threads(args.get_usize("threads", 0));
    let n = args.get_usize("n", 600);
    let lambda = args.get_f64("lambda", 1e-2);
    let sigma = args.get_f64("sigma", 3.0);
    let seed = args.get_u64("seed", 7);
    let reps = args.get_usize("reps", 3);
    let specs = parse_specs(&args, &ShootoutConfig::default().specs);

    println!(
        "estimator shoot-out: n={n} λ={lambda:.1e} σ={sigma} reps={reps} seed={seed} \
         threads={}",
        pool::threads()
    );
    let ds = susy_like(n, &mut Rng::seeded(seed.wrapping_add(77)));
    let eng = NativeEngine::new(ds.x, Gaussian::new(sigma));
    let cfg = ShootoutConfig { lambda, reps, seed, specs: specs.clone() };
    let shoot = fig1_estimator_shootout(&eng, &cfg).expect("shoot-out");
    println!("{}", shoot.to_console());

    // small size sweep → per-estimator empirical cost exponent in n
    let sizes: Vec<usize> = args
        .get("sizes")
        .map(|s| s.split(',').map(|v| v.trim().parse().expect("bad --sizes")).collect())
        .unwrap_or_else(|| vec![n / 2, n]);
    let sweep_cfg =
        Fig2Config { sizes: sizes.clone(), sigma, lambda, seed, ..Default::default() };
    let sweep = fig2_estimator_scaling(&sweep_cfg, &specs).expect("estimator sweep");
    println!("{}", sweep.to_console());
    let mut slopes: Vec<(String, f64)> = Vec::new();
    if sizes.len() >= 2 {
        for spec in &specs {
            let name = parse_estimator(spec).expect("spec parsed above").name();
            let s = scaling_exponent_for(&sweep, &name);
            println!("  {name:<22} empirical n-exponent: {s:.3}");
            slopes.push((name, s));
        }
    }

    // --- BENCH_estimators.json (repo-root schema: flat metric object)
    if let Some(out) = args.get("out") {
        let mut obj = BTreeMap::new();
        let mut put = |k: String, v: f64| {
            obj.insert(k, Json::Num(v));
        };
        put("threads".into(), pool::threads() as f64);
        put("n".into(), n as f64);
        put("lambda".into(), lambda);
        put("reps".into(), reps as f64);
        put("seed".into(), seed as f64);
        // shoot-out columns: estimator time_s R-ACC q05 q95 kernel_evals peak_MB
        for row in &shoot.rows {
            let p = sanitize(&row[0]);
            let f = |s: &str| s.parse::<f64>().expect("numeric table cell");
            put(format!("{p}_time_s"), f(&row[1]));
            put(format!("{p}_racc_mean"), f(&row[2]));
            put(format!("{p}_racc_q05"), f(&row[3]));
            put(format!("{p}_racc_q95"), f(&row[4]));
            put(format!("{p}_kernel_evals"), f(&row[5]));
            put(format!("{p}_peak_mb"), f(&row[6]));
        }
        for (name, s) in &slopes {
            put(format!("{}_n_exponent", sanitize(name)), *s);
        }
        obj.insert("bench".to_string(), Json::Str("estimators".to_string()));
        std::fs::write(out, Json::Obj(obj).to_string()).expect("writing BENCH json");
        println!("wrote {out}");
    }
}
