//! Bench: Figure 1 — R-ACC accuracy/time table for all samplers against
//! exact leverage scores (time dominated by the exact reference).

use bless::coordinator::{build_engine, fig1_accuracy, EngineKind, Fig1Config};
use bless::data::susy_like;
use bless::kernels::Gaussian;
use bless::rng::Rng;

fn main() {
    let cfg = Fig1Config { n: 1_500, reps: 3, lambda: 1e-4, ..Default::default() };
    let ds = susy_like(cfg.n, &mut Rng::seeded(cfg.seed.wrapping_add(77)));
    let eng = build_engine(EngineKind::Native, ds.x, Gaussian::new(cfg.sigma)).unwrap();
    let t = fig1_accuracy(eng.as_dyn(), &cfg).expect("fig1");
    println!("{}", t.to_console());
}
