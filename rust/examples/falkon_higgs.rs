//! Figure-5 driver: FALKON-BLESS vs FALKON-UNI on HIGGS-like data
//! (28 features, weaker class separation than SUSY).
//!
//! ```bash
//! cargo run --release --example falkon_higgs -- --n 8000
//! ```

use bless::coordinator::{build_engine, fig45_falkon, EngineKind, Fig45Config};
use bless::data::higgs_like;
use bless::kernels::Gaussian;
use bless::rng::Rng;
use bless::util::cli::Args;
use bless::util::table::fnum;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let n = args.get_usize("n", 8_000);
    let seed = args.get_u64("seed", 0);
    let mut rng = Rng::seeded(seed);
    let ds = higgs_like(n, &mut rng);
    let (train, test) = ds.split(0.25, &mut rng);

    let mut cfg = Fig45Config::higgs();
    cfg.iterations = args.get_usize("iters", 20);
    cfg.lambda_bless = args.get_f64("lambda-bless", cfg.lambda_bless);
    cfg.lambda_falkon = args.get_f64("lambda-falkon", cfg.lambda_falkon);
    cfg.seed = seed;

    let kind = EngineKind::parse(&args.get_str("engine", "native")).unwrap();
    let engine = build_engine(kind, train.x.clone(), Gaussian::new(cfg.sigma))?;
    println!(
        "HIGGS-like: train n={} test n={} engine={}",
        train.n(),
        test.n(),
        engine.label()
    );

    let (b, u, table) = fig45_falkon(engine.as_dyn(), &train.y, &test, &cfg)?;
    println!("{}", table.to_console());
    println!("{}: M={} final AUC {}", b.label, b.centers, fnum(b.final_auc()));
    println!("{}: M={} final AUC {}", u.label, u.centers, fnum(u.final_auc()));
    Ok(())
}
