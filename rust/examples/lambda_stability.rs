//! Figure-3 driver: classification error after 5 FALKON iterations
//! across a λ_falkon sweep — BLESS centers widen the near-optimal region.
//!
//! ```bash
//! cargo run --release --example lambda_stability -- --n 4000
//! ```

use bless::coordinator::{build_engine, fig3_stability, EngineKind, Fig3Config};
use bless::data::susy_like;
use bless::kernels::Gaussian;
use bless::rng::Rng;
use bless::util::cli::Args;
use bless::util::table::fnum;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let n = args.get_usize("n", 4_000);
    let seed = args.get_u64("seed", 0);
    let mut rng = Rng::seeded(seed);
    let ds = susy_like(n, &mut rng);
    let (train, test) = ds.split(0.25, &mut rng);
    let cfg = Fig3Config {
        sigma: args.get_f64("sigma", 4.0),
        lambda_bless: args.get_f64("lambda-bless", 1e-3),
        iterations: args.get_usize("iters", 5),
        seed,
        ..Default::default()
    };
    let kind = EngineKind::parse(&args.get_str("engine", "native")).unwrap();
    let engine = build_engine(kind, train.x.clone(), Gaussian::new(cfg.sigma))?;
    let res = fig3_stability(engine.as_dyn(), &train.y, &test, &cfg)?;
    println!("{}", res.table.to_console());
    println!(
        "95%-optimal λ region: BLESS {} decades vs UNI {} decades",
        fnum(res.bless_region_decades),
        fnum(res.uni_region_decades)
    );
    Ok(())
}
