//! Figure-2 driver: sampler runtime as n grows at fixed λ — BLESS and
//! BLESS-R stay flat (O(1/λ)) while the baselines grow linearly.
//!
//! ```bash
//! cargo run --release --example runtime_scaling -- --sizes 1000,2000,4000,8000
//! ```

use bless::coordinator::{fig2_scaling, scaling_exponent, Fig2Config};
use bless::util::cli::Args;
use bless::util::table::fnum;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let sizes = args
        .get("sizes")
        .map(|s| s.split(',').map(|v| v.trim().parse().unwrap()).collect())
        .unwrap_or_else(|| vec![1_000, 2_000, 4_000, 8_000]);
    let cfg = Fig2Config {
        sizes,
        lambda: args.get_f64("lambda", 1e-3),
        sigma: args.get_f64("sigma", 4.0),
        seed: args.get_u64("seed", 0),
        ..Default::default()
    };
    let table = fig2_scaling(&cfg);
    println!("{}", table.to_console());
    println!("empirical log-log slope of time vs n (theory: 0 for BLESS/BLESS-R, 1 otherwise):");
    for &m in &cfg.methods {
        println!("  {:<10} {}", m.name(), fnum(scaling_exponent(&table, m)));
    }
    Ok(())
}
