//! Figure-1 driver: leverage-score relative accuracy (R-ACC) of BLESS,
//! BLESS-R, SQUEAK, RRLS, Two-Pass and Uniform against exact scores.
//!
//! ```bash
//! cargo run --release --example leverage_accuracy -- --n 2000 --lambda 1e-4 --reps 5
//! ```

use bless::coordinator::{build_engine, fig1_accuracy, EngineKind, Fig1Config};
use bless::data::susy_like;
use bless::kernels::Gaussian;
use bless::rng::Rng;
use bless::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let cfg = Fig1Config {
        n: args.get_usize("n", 2_000),
        lambda: args.get_f64("lambda", 1e-4),
        sigma: args.get_f64("sigma", 4.0),
        reps: args.get_usize("reps", 5),
        seed: args.get_u64("seed", 0),
        uniform_m: args.get_usize("uniform-m", 400),
        ..Default::default()
    };
    let ds = susy_like(cfg.n, &mut Rng::seeded(cfg.seed.wrapping_add(77)));
    let kind = EngineKind::parse(&args.get_str("engine", "native")).unwrap();
    let engine = build_engine(kind, ds.x, Gaussian::new(cfg.sigma))?;
    let table = fig1_accuracy(engine.as_dyn(), &cfg)?;
    println!("{}", table.to_console());
    println!("{}", table.to_markdown());
    Ok(())
}
