//! Quickstart: sample Nyström centers with BLESS and train FALKON-BLESS
//! on a small synthetic problem — the 60-second tour of the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use bless::bless::{bless, BlessConfig};
use bless::coordinator::{build_engine, EngineKind};
use bless::data::{auc, susy_like};
use bless::falkon::Falkon;
use bless::kernels::Gaussian;
use bless::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. data: SUSY-like synthetic events (18 features, ±1 labels)
    let mut rng = Rng::seeded(42);
    let ds = susy_like(3_000, &mut rng);
    let (train, test) = ds.split(0.25, &mut rng);
    println!("train n={} d={} | test n={}", train.n(), train.d(), test.n());

    // 2. engine: prefers the AOT-compiled Pallas tiles (make artifacts),
    //    falls back to the native rust backend
    let engine = build_engine(EngineKind::Auto, train.x.clone(), Gaussian::new(4.0))?;
    println!("kernel engine backend: {}", engine.label());

    // 3. BLESS: leverage-score sampling along the regularization path
    let lambda_bless = 1e-3;
    let t0 = std::time::Instant::now();
    let path = bless(engine.as_dyn(), lambda_bless, &BlessConfig::default(), &mut rng);
    println!(
        "BLESS: {} levels, final |J| = {} ({} score evals, {:.2}s)",
        path.levels.len(),
        path.final_set().len(),
        path.score_evals,
        t0.elapsed().as_secs_f64()
    );
    for l in &path.levels {
        println!("  λ={:<9.2e} |J|={:<5} d̂_eff={:.1}", l.lambda, l.set.len(), l.d_est);
    }

    // 4. FALKON with the BLESS centers + weights (Eq. 15 preconditioner)
    let lambda_falkon = 1e-5;
    let set = path.final_set().clone();
    let solver = Falkon::new(engine.as_dyn(), &set, lambda_falkon)?;
    let model = solver.fit(&train.y, 15, None)?;
    let scores = model.predict(engine.as_dyn(), &test.x);
    println!("FALKON-BLESS: M={} test AUC = {:.4}", solver.m(), auc(&scores, &test.y));
    Ok(())
}
