//! **End-to-end driver** (Figure 4): the full three-layer system on a
//! real small workload — SUSY-like events, BLESS center sampling,
//! FALKON preconditioned CG, per-iteration held-out AUC for BLESS vs
//! uniform centers. This is the repo's system-level validation run;
//! its output is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example falkon_susy -- --n 8000 --engine auto
//! ```

use bless::coordinator::{build_engine, fig45_falkon, EngineKind, Fig45Config};
use bless::data::susy_like;
use bless::kernels::Gaussian;
use bless::rng::Rng;
use bless::util::cli::Args;
use bless::util::table::fnum;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let n = args.get_usize("n", 8_000);
    let seed = args.get_u64("seed", 0);
    let mut rng = Rng::seeded(seed);
    let ds = susy_like(n, &mut rng);
    let (train, test) = ds.split(0.25, &mut rng);

    let mut cfg = Fig45Config::susy();
    cfg.iterations = args.get_usize("iters", 20);
    cfg.lambda_bless = args.get_f64("lambda-bless", cfg.lambda_bless);
    cfg.lambda_falkon = args.get_f64("lambda-falkon", cfg.lambda_falkon);
    cfg.seed = seed;

    let kind = EngineKind::parse(&args.get_str("engine", "native")).unwrap();
    let engine = build_engine(kind, train.x.clone(), Gaussian::new(cfg.sigma))?;
    println!(
        "SUSY-like end-to-end: train n={} test n={} engine={}",
        train.n(),
        test.n(),
        engine.label()
    );

    let (b, u, table) = fig45_falkon(engine.as_dyn(), &train.y, &test, &cfg)?;
    println!("{}", table.to_console());
    println!(
        "{}: M={}, sampling {}s, final AUC {}",
        b.label,
        b.centers,
        fnum(b.sampling_secs),
        fnum(b.final_auc())
    );
    println!("{}: M={}, final AUC {}", u.label, u.centers, fnum(u.final_auc()));
    if let Some(it) = b.iters_to_reach(u.final_auc()) {
        let t_b = b.points[it - 1].1;
        let t_u = u.points.last().map(|p| p.1).unwrap_or(0.0);
        println!(
            "FALKON-BLESS matches FALKON-UNI's final AUC at iter {it} \
             ({}s vs {}s ⇒ {:.1}x speedup)",
            fnum(t_b),
            fnum(t_u),
            t_u / t_b.max(1e-9)
        );
    }
    Ok(())
}
