//! Serve round-trip: train → save → load → serve → predict over TCP.
//!
//! The full deployment story in one file: BLESS picks centers, FALKON
//! fits α, the model is packaged into a self-contained artifact, a
//! prediction server is started from the *loaded* artifact, and a TCP
//! client scores held-out points — checked against the in-process
//! predictions.
//!
//! ```bash
//! cargo run --release --example serve_roundtrip
//! ```

use bless::bless::{bless, BlessConfig};
use bless::data::susy_like;
use bless::falkon::Falkon;
use bless::kernels::{Gaussian, NativeEngine};
use bless::rng::Rng;
use bless::serve::{self, Client, ModelArtifact, Predictor, ServeConfig};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // 1. train: BLESS centers + FALKON coefficients
    let mut rng = Rng::seeded(42);
    let ds = susy_like(2_000, &mut rng);
    let (train, test) = ds.split(0.25, &mut rng);
    let eng = NativeEngine::new(train.x.clone(), Gaussian::new(4.0));
    let path = bless(&eng, 1e-3, &BlessConfig::default(), &mut rng);
    let model = Falkon::new(&eng, path.final_set(), 1e-5)?.fit(&train.y, 12, None)?;
    println!("trained: M={} centers on n={}", model.centers.len(), train.n());

    // 2. save the self-contained artifact (centers + α + kernel config)
    let artifact_path = std::env::temp_dir()
        .join(format!("bless-serve-roundtrip-{}.json", std::process::id()));
    ModelArtifact::from_fitted(&model, &eng, &train.name)?.save(&artifact_path)?;
    println!("saved artifact: {}", artifact_path.display());

    // 3. load it back — no training data needed from here on
    let artifact = ModelArtifact::load(&artifact_path)?;
    let reference = Predictor::new(&artifact);

    // 4. serve it and score held-out points over TCP
    let cfg = ServeConfig::builder()
        .addr("127.0.0.1:0") // ephemeral port
        .workers(2)
        .max_batch(32)
        .linger(Duration::from_millis(2))
        .build()?;
    let handle = serve::start(artifact, &cfg)?;
    println!("serving on {}", handle.addr());

    let mut client = Client::connect(handle.addr())?;
    let mut worst = 0.0f64;
    for i in 0..10 {
        let q = test.x.row(i);
        let (served, cached) = client.predict(i as u64, q)?;
        let direct = reference.predict_one(q)?;
        worst = worst.max((served - direct).abs());
        println!("query {i}: served {served:+.6} direct {direct:+.6} cached={cached}");
    }
    let stats = client.stats()?;
    client.shutdown()?;
    handle.join();
    std::fs::remove_file(&artifact_path).ok();

    println!(
        "requests={} mean_batch={:.2} cache_hits={} | worst |served-direct| = {worst:.2e}",
        stats.requests,
        stats.mean_batch(),
        stats.cache_hits
    );
    anyhow::ensure!(worst < 1e-10, "served predictions drifted from direct path");
    println!("round trip OK");
    Ok(())
}
